//! End-to-end smoke: convnet and transformer artifacts through the full
//! stack (PJRT fwd/bwd → compression → collective → EF-SGD update).
//! Requires `make artifacts` (skips gracefully otherwise).

use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::{Classification, LmCorpus};
use powersgd::optim::{EfSgd, LrSchedule};
use powersgd::runtime::Runtime;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("convnet_train.manifest").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

#[test]
fn convnet_loss_decreases_with_powersgd() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    let train = rt.load("convnet_train").unwrap();
    let eval = rt.load("convnet_eval").unwrap();
    let opt = Box::new(EfSgd::new(
        Box::new(PowerSgd::new(2, 1)),
        LrSchedule::constant(0.02),
        0.9,
    ));
    let cfg = TrainerConfig { workers: 2, eval_kind: EvalKind::Accuracy, ..Default::default() };
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg).unwrap();
    let mut data = Classification::new(3 * 16 * 16, 10, 32, 2, 42);
    let mut first = 0.0;
    for step in 0..40 {
        let loss = trainer.train_step(&mut data).unwrap();
        if step == 0 {
            first = loss;
        }
    }
    let last = trainer.metrics.mean_loss_last(5);
    assert!(last < first * 0.9, "convnet loss {first} -> {last}");
    // conv gradients matricize per the paper: [o,i,kh,kw] -> [o, i·kh·kw]
    let reg = trainer.registry();
    let spec = &reg.specs[1]; // b1.conv1: 16×16×3×3
    assert_eq!(spec.matrix_dims(), Some((16, 144)));
}

#[test]
fn transformer_tiny_loss_decreases() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    let train = rt.load("transformer_tiny_train").unwrap();
    let eval = rt.load("transformer_tiny_eval").unwrap();
    let opt = Box::new(EfSgd::new(
        Box::new(PowerSgd::new(4, 1)),
        LrSchedule::constant(0.05),
        0.9,
    ));
    let cfg = TrainerConfig { workers: 2, eval_kind: EvalKind::Perplexity, ..Default::default() };
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg).unwrap();
    let mut data = LmCorpus::new(2000, 8, 64, 2, 42);
    let ppl0 = trainer.evaluate(&mut data).unwrap();
    trainer.train(&mut data, 30).unwrap();
    let ppl1 = trainer.evaluate(&mut data).unwrap();
    assert!(ppl1 < ppl0, "transformer ppl {ppl0} -> {ppl1}");
    // compression ratio at rank 4 should be large for this model
    let reg = trainer.registry();
    assert!(reg.compression_ratio(4) > 5.0);
}

#[test]
fn single_vs_multi_worker_equivalence_through_full_stack() {
    // Lemma 3 at system level: W workers with batch B each must produce
    // the same parameter trajectory as 1 worker whose gradient is the
    // mean — we verify the compressed aggregate path by running the same
    // total batch through different worker counts and checking losses
    // stay within stochastic-ordering distance (identical seeds make
    // the *data* differ across shardings, so we compare convergence, not
    // bitwise equality — bitwise equivalence is covered by the unit
    // tests on the compressor itself).
    let Some(dir) = artifacts_dir() else { return };
    let run = |workers: usize| {
        let mut rt = Runtime::cpu(&dir).unwrap();
        let train = rt.load("mlp_train").unwrap();
        let opt = Box::new(EfSgd::new(
            Box::new(PowerSgd::new(2, 1)),
            LrSchedule::constant(0.05),
            0.9,
        ));
        let cfg = TrainerConfig { workers, ..Default::default() };
        let mut trainer = Trainer::new(train, None, opt, cfg).unwrap();
        let mut data = Classification::new(64, 10, 32, workers, 11);
        trainer.train(&mut data, 120).unwrap();
        trainer.metrics.mean_loss_last(10)
    };
    let l1 = run(1);
    let l4 = run(4);
    assert!(l4 < l1 * 1.5 + 0.2, "4-worker {l4} vs 1-worker {l1}");
}
