//! Differential kernel-equivalence harness (DESIGN.md §11).
//!
//! The blocked, register-tiled GEMM kernels and the fused Gram–Schmidt
//! sweep are compared against the naive reference backend
//! (`KernelBackend::Reference`) over degenerate, odd, prime and
//! chunk-boundary shapes, at thread counts {1, 2, 4, 8}, through the
//! *public dispatch path* (the process backend is flipped, not the
//! internals called directly). The contract, per kernel:
//!
//! - `matmul_tn_into` / `matmul_nt_into`: the blocked kernels keep the
//!   reference per-element accumulation chain — outputs must be equal
//!   on every element (`==`; the only representational slack is the
//!   sign of an exact zero).
//! - `matmul_into`: the blocked kernel splits the k dimension over 8
//!   lanes — the one documented GEMM numerics change. Bounded here in
//!   ULPs (with an absolute floor for cancellation-collapsed outputs);
//!   the exact accumulation order is pinned by the executable lane
//!   spec in `tensor/matmul.rs`.
//! - `gram_schmidt_in_place`: fused right-looking sweep vs textbook
//!   serial left-looking loop — equal (`==`) for `n ≤ REDUCE_CHUNK`
//!   where the chunked reductions degenerate to one serial stream,
//!   ULP-bounded above it (the documented reduction-chunking change).
//! - Full PowerSGD steps: bitwise thread-count invariant *within*
//!   each backend; agreeing to working precision *across* backends.
//!
//! Both the thread count and the backend are process globals, so every
//! test here serializes on one lock and restores the ambient values.

use powersgd::collectives::CommLog;
use powersgd::compress::{Compressor, PowerSgd};
use powersgd::linalg::gram_schmidt_in_place;
use powersgd::runtime::pool::{
    kernel_backend, set_kernel_backend, set_threads, threads, KernelBackend, REDUCE_CHUNK,
};
use powersgd::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Tensor};
use powersgd::util::Rng;
use std::sync::{Mutex, MutexGuard};

static GLOBALS_LOCK: Mutex<()> = Mutex::new(());

/// Serializes every test in this binary (all of them flip the
/// process-global backend and/or thread count) and restores the
/// ambient values on drop, so a `POWERSGD_THREADS=4` CI pass keeps its
/// configuration across tests.
struct GlobalsGuard {
    _guard: MutexGuard<'static, ()>,
    ambient_threads: usize,
    ambient_backend: KernelBackend,
}

impl Drop for GlobalsGuard {
    fn drop(&mut self) {
        set_threads(self.ambient_threads);
        set_kernel_backend(self.ambient_backend);
    }
}

fn lock() -> GlobalsGuard {
    let guard = GLOBALS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    GlobalsGuard {
        _guard: guard,
        ambient_threads: threads(),
        ambient_backend: kernel_backend(),
    }
}

const SWEEP: [usize; 4] = [1, 2, 4, 8];

/// Degenerate, odd, prime, and chunk-boundary shapes: (n, m, r).
/// 509 and 1031 are prime; 4096/4097 straddle REDUCE_CHUNK.
const SHAPES: [(usize, usize, usize); 7] = [
    (1, 1, 1),
    (7, 13, 3),
    (63, 63, 5),
    (509, 127, 7),
    (4096, 300, 2),
    (4097, 96, 8),
    (40, 1031, 4),
];

fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

/// Distance in units-in-the-last-place between two finite f32s, via
/// the monotone integer mapping (±0.0 are 0 apart).
fn ulp_dist(a: f32, b: f32) -> u64 {
    fn key(x: f32) -> i64 {
        let i = x.to_bits() as i32;
        (if i < 0 { i32::MIN - i } else { i }) as i64
    }
    (key(a) - key(b)).unsigned_abs()
}

/// Every element within `max_ulp` ULPs, with an absolute floor for
/// outputs that cancellation collapsed toward zero (where ULP distance
/// is meaningless but the absolute error is still tiny).
fn assert_ulp_close(got: &Tensor, want: &Tensor, max_ulp: u64, abs_floor: f32, ctx: &str) {
    assert_eq!(got.shape(), want.shape(), "{ctx}: shape");
    for (i, (&a, &b)) in got.data().iter().zip(want.data().iter()).enumerate() {
        assert!(a.is_finite() && b.is_finite(), "{ctx}: non-finite at {i}: {a} vs {b}");
        let d = ulp_dist(a, b);
        assert!(
            d <= max_ulp || (a - b).abs() <= abs_floor,
            "{ctx}: element {i} differs by {d} ULPs ({a} vs {b})"
        );
    }
}

#[test]
fn ulp_dist_is_sane() {
    let _g = lock();
    assert_eq!(ulp_dist(1.0, 1.0), 0);
    assert_eq!(ulp_dist(0.0, -0.0), 0);
    assert_eq!(ulp_dist(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
    assert!(ulp_dist(-1.0, 1.0) > 1 << 24);
}

/// tn and nt keep the reference accumulation chain: `==`-equal output
/// at every shape and thread count, through the dispatch path.
#[test]
fn tn_nt_blocked_equal_reference_across_threads() {
    let _g = lock();
    let mut rng = Rng::new(401);
    for &(n, m, r) in &SHAPES {
        let a = rand_tensor(&[n, m], &mut rng);
        let p = rand_tensor(&[n, r], &mut rng);
        let q = rand_tensor(&[m, r], &mut rng);
        set_kernel_backend(KernelBackend::Reference);
        set_threads(1);
        let mut tn_ref = Tensor::zeros(&[m, r]);
        matmul_tn_into(&a, &p, &mut tn_ref);
        let mut nt_ref = Tensor::zeros(&[n, m]);
        matmul_nt_into(&p, &q, &mut nt_ref);
        for &t in &SWEEP {
            set_threads(t);
            set_kernel_backend(KernelBackend::Blocked);
            let mut got = Tensor::zeros(&[m, r]);
            matmul_tn_into(&a, &p, &mut got);
            assert_eq!(got.data(), tn_ref.data(), "tn n={n} m={m} r={r} t={t}");
            let mut got = Tensor::zeros(&[n, m]);
            matmul_nt_into(&p, &q, &mut got);
            assert_eq!(got.data(), nt_ref.data(), "nt n={n} m={m} r={r} t={t}");
            // The reference backend is itself thread-count invariant —
            // the premise that lets one serial reference serve the
            // whole sweep.
            set_kernel_backend(KernelBackend::Reference);
            let mut got = Tensor::zeros(&[m, r]);
            matmul_tn_into(&a, &p, &mut got);
            assert_eq!(got.data(), tn_ref.data(), "ref tn n={n} m={m} r={r} t={t}");
            let mut got = Tensor::zeros(&[n, m]);
            matmul_nt_into(&p, &q, &mut got);
            assert_eq!(got.data(), nt_ref.data(), "ref nt n={n} m={m} r={r} t={t}");
        }
    }
}

/// nn is the documented numerics change (8-lane k split): ULP-bounded
/// against the reference at every shape and thread count, and bitwise
/// thread-count invariant within the blocked backend.
#[test]
fn nn_blocked_vs_reference_ulp_bounded_across_threads() {
    let _g = lock();
    // Lane-split vs serial sums of ~N(0,1) products drift by
    // O(sqrt(m)) ULPs; 1024 covers m ≤ 1031 with an order of margin
    // while still catching any dropped/duplicated term (which shows up
    // as an O(1) = millions-of-ULPs error). The absolute floor covers
    // outputs cancellation pushed toward zero.
    const MAX_ULP: u64 = 1024;
    const ABS_FLOOR: f32 = 1e-3;
    let mut rng = Rng::new(402);
    for &(n, m, r) in &SHAPES {
        let a = rand_tensor(&[n, m], &mut rng);
        let b = rand_tensor(&[m, r], &mut rng);
        set_kernel_backend(KernelBackend::Reference);
        set_threads(1);
        let mut nn_ref = Tensor::zeros(&[n, r]);
        matmul_into(&a, &b, &mut nn_ref);
        set_kernel_backend(KernelBackend::Blocked);
        let mut serial = Tensor::zeros(&[n, r]);
        matmul_into(&a, &b, &mut serial);
        assert_ulp_close(&serial, &nn_ref, MAX_ULP, ABS_FLOOR, &format!("nn n={n} m={m} r={r}"));
        for &t in &SWEEP[1..] {
            set_threads(t);
            let mut got = Tensor::zeros(&[n, r]);
            matmul_into(&a, &b, &mut got);
            assert_eq!(got.data(), serial.data(), "blocked nn invariance n={n} m={m} r={r} t={t}");
        }
    }
}

/// Fused Gram–Schmidt vs the textbook serial reference: `==`-equal up
/// to the reduction chunk, ULP-bounded above it, at every thread
/// count; rank-deficient and all-zero edges take identical paths.
#[test]
fn gram_schmidt_fused_vs_reference_across_threads() {
    let _g = lock();
    let mut rng = Rng::new(403);
    // (n, r): below/at the chunk boundary → exact; above → ULP-bounded.
    let shapes: [(usize, usize); 7] =
        [(1, 1), (7, 3), (63, 5), (509, 8), (REDUCE_CHUNK, 4), (REDUCE_CHUNK + 1, 3), (9000, 4)];
    for &(n, r) in &shapes {
        let p0 = rand_tensor(&[n, r], &mut rng);
        set_kernel_backend(KernelBackend::Reference);
        set_threads(1);
        let mut want = p0.clone();
        gram_schmidt_in_place(&mut want);
        for &t in &SWEEP {
            set_threads(t);
            set_kernel_backend(KernelBackend::Blocked);
            let mut got = p0.clone();
            gram_schmidt_in_place(&mut got);
            if n <= REDUCE_CHUNK {
                assert_eq!(got.data(), want.data(), "gs n={n} r={r} t={t}");
            } else {
                assert_ulp_close(&got, &want, 64, 1e-5, &format!("gs n={n} r={r} t={t}"));
            }
            set_kernel_backend(KernelBackend::Reference);
            let mut got = p0.clone();
            gram_schmidt_in_place(&mut got);
            assert_eq!(got.data(), want.data(), "ref gs invariance n={n} r={r} t={t}");
        }
    }
}

#[test]
fn gram_schmidt_edges_identical_on_both_backends() {
    let _g = lock();
    let n = REDUCE_CHUNK - 37; // below the chunk: contract promises ==
    let mut rng = Rng::new(404);
    // Middle column duplicates column 0: it must be zeroed (not
    // normalized noise) by BOTH backends, and the later column's
    // result must agree exactly.
    let mut dup = Tensor::zeros(&[n, 3]);
    rng.fill_normal(dup.data_mut(), 1.0);
    for i in 0..n {
        let v = dup.at(i, 0);
        dup.set(i, 1, v);
    }
    let zero = Tensor::zeros(&[n, 2]);
    for &t in &SWEEP {
        set_threads(t);
        set_kernel_backend(KernelBackend::Reference);
        let mut want_dup = dup.clone();
        gram_schmidt_in_place(&mut want_dup);
        let mut want_zero = zero.clone();
        gram_schmidt_in_place(&mut want_zero);
        set_kernel_backend(KernelBackend::Blocked);
        let mut got_dup = dup.clone();
        gram_schmidt_in_place(&mut got_dup);
        let mut got_zero = zero.clone();
        gram_schmidt_in_place(&mut got_zero);
        assert_eq!(got_dup.data(), want_dup.data(), "rank-deficient t={t}");
        let dep: f64 = (0..n).map(|i| (got_dup.at(i, 1) as f64).powi(2)).sum::<f64>().sqrt();
        assert!(dep == 0.0, "dependent column must be exactly zero, norm {dep} t={t}");
        assert_eq!(got_zero.data(), want_zero.data(), "all-zero t={t}");
        assert!(got_zero.data().iter().all(|&v| v == 0.0), "all-zero stays zero t={t}");
    }
}

/// Full warm-started PowerSGD steps: bitwise thread-count invariant
/// within each backend, and agreeing to working precision across
/// backends (the nn lane split propagates through the step).
#[test]
fn powersgd_step_cross_backend() {
    let _g = lock();
    let shapes: [&[usize]; 4] = [&[4500, 64], &[12, 8], &[5], &[64, 80]];
    let steps = 3;
    let workers = 2;
    let updates_for = |step: usize| -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(950 + step as u64);
        (0..workers)
            .map(|_| shapes.iter().map(|s| rand_tensor(s, &mut rng)).collect())
            .collect()
    };
    let run = |backend: KernelBackend, t: usize| -> Vec<Vec<Tensor>> {
        set_kernel_backend(backend);
        set_threads(t);
        let mut comp = PowerSgd::new(2, 17);
        let mut means = Vec::new();
        for step in 0..steps {
            let mut log = CommLog::default();
            means.push(comp.compress_aggregate(&updates_for(step), &mut log).mean);
        }
        means
    };

    let blocked = run(KernelBackend::Blocked, 1);
    let reference = run(KernelBackend::Reference, 1);
    // Within-backend invariance (the blocked sweep at {2,4,8} is
    // already pinned by integration_kernels; cover reference here).
    for &t in &[4usize, 8] {
        let again = run(KernelBackend::Reference, t);
        for (step, (a, b)) in again.iter().zip(reference.iter()).enumerate() {
            for (p, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.data(), y.data(), "reference step {step} mean[{p}] t={t}");
            }
        }
    }
    // Cross-backend: same math, ULP-level divergence amplified through
    // three warm-started steps — working-precision agreement.
    for (step, (a, b)) in blocked.iter().zip(reference.iter()).enumerate() {
        for (p, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.shape(), y.shape(), "step {step} mean[{p}] shape");
            assert!(
                x.allclose(y, 1e-3, 1e-3),
                "step {step} mean[{p}] cross-backend, max diff {}",
                x.max_abs_diff(y)
            );
        }
    }
}
