//! Integration: the full coordinator over real PJRT artifacts.
//! Requires `make artifacts` (skips gracefully otherwise).

use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::{Classification, LmCorpus};
use powersgd::optim::{EfSgd, LrSchedule, Sgd};
use powersgd::runtime::Runtime;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("mlp_train.manifest").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

fn mlp_trainer(dir: &str, opt: Box<dyn powersgd::optim::DistOptimizer>, workers: usize) -> Trainer {
    let mut rt = Runtime::cpu(dir).unwrap();
    let train = rt.load("mlp_train").unwrap();
    let eval = rt.load("mlp_eval").unwrap();
    let cfg = TrainerConfig {
        workers,
        eval_every: 0,
        eval_kind: EvalKind::Accuracy,
        ..Default::default()
    };
    Trainer::new(train, Some(eval), opt, cfg).unwrap()
}

#[test]
fn mlp_powersgd_trains_to_high_accuracy() {
    let Some(dir) = artifacts_dir() else { return };
    let opt = Box::new(EfSgd::new(
        Box::new(PowerSgd::new(2, 1)),
        LrSchedule::constant(0.08),
        0.9,
    ));
    let mut trainer = mlp_trainer(&dir, opt, 4);
    let mut data = Classification::new(64, 10, 32, 4, 42);
    trainer.train(&mut data, 250).unwrap();
    let acc = trainer.evaluate(&mut data).unwrap();
    assert!(acc > 75.0, "accuracy {acc}");
    // communication volume: rank-2 message ≪ full gradient
    let per_step = trainer.metrics.total_bytes() / 250;
    assert!(per_step < trainer.registry().total_bytes() / 5, "{per_step}");
}

#[test]
fn sgd_baseline_trains_and_sends_full_gradients() {
    let Some(dir) = artifacts_dir() else { return };
    let opt = Box::new(Sgd::new(LrSchedule::constant(0.08), 0.9));
    let mut trainer = mlp_trainer(&dir, opt, 2);
    let mut data = Classification::new(64, 10, 32, 2, 42);
    trainer.train(&mut data, 200).unwrap();
    let acc = trainer.evaluate(&mut data).unwrap();
    assert!(acc > 75.0, "accuracy {acc}");
    assert_eq!(
        trainer.metrics.total_bytes() / 200,
        trainer.registry().total_bytes()
    );
}

#[test]
fn error_feedback_ablation_orders_correctly() {
    // Fig. 7 (Appendix E): PowerSGD without error feedback does not
    // converge to a good accuracy — the rank-1 approximation permanently
    // discards the orthogonal complement of every gradient.
    let Some(dir) = artifacts_dir() else { return };
    let run = |ef: bool| {
        let inner = Box::new(PowerSgd::new(1, 3));
        let mut opt = EfSgd::new(inner, LrSchedule::constant(0.08), 0.9);
        if !ef {
            opt = opt.without_error_feedback();
        }
        let mut trainer = mlp_trainer(&dir, Box::new(opt), 2);
        let mut data = Classification::new(64, 10, 32, 2, 7);
        trainer.train(&mut data, 300).unwrap();
        trainer.evaluate(&mut data).unwrap()
    };
    let with_ef = run(true);
    let without_ef = run(false);
    assert!(
        with_ef > without_ef + 5.0,
        "EF {with_ef}% must beat no-EF {without_ef}% clearly"
    );
}

#[test]
fn lstm_perplexity_drops() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    let train = rt.load("lstm_train").unwrap();
    let eval = rt.load("lstm_eval").unwrap();
    let opt = Box::new(EfSgd::new(
        Box::new(PowerSgd::new(4, 2)),
        LrSchedule::constant(0.5),
        0.9,
    ));
    let cfg = TrainerConfig {
        workers: 2,
        eval_kind: EvalKind::Perplexity,
        ..Default::default()
    };
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg).unwrap();
    let mut data = LmCorpus::new(1000, 8, 32, 2, 5);
    let ppl0 = trainer.evaluate(&mut data).unwrap();
    trainer.train(&mut data, 60).unwrap();
    let ppl1 = trainer.evaluate(&mut data).unwrap();
    assert!(
        ppl1 < ppl0 * 0.7,
        "perplexity should drop substantially: {ppl0} -> {ppl1}"
    );
}

#[test]
fn deterministic_given_seed() {
    let Some(dir) = artifacts_dir() else { return };
    let run = || {
        let opt = Box::new(EfSgd::new(
            Box::new(PowerSgd::new(2, 1)),
            LrSchedule::constant(0.05),
            0.9,
        ));
        let mut trainer = mlp_trainer(&dir, opt, 2);
        let mut data = Classification::new(64, 10, 32, 2, 9);
        trainer.train(&mut data, 20).unwrap();
        trainer.metrics.mean_loss_last(5)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must reproduce the loss trajectory");
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some(dir) = artifacts_dir() else { return };
    let opt = Box::new(EfSgd::new(
        Box::new(PowerSgd::new(2, 1)),
        LrSchedule::constant(0.05),
        0.9,
    ));
    let mut trainer = mlp_trainer(&dir, opt, 2);
    let mut data = Classification::new(64, 10, 32, 2, 13);
    trainer.train(&mut data, 30).unwrap();
    let ckpt = std::env::temp_dir().join("powersgd_trainer_ckpt.bin");
    trainer.save_checkpoint(&ckpt).unwrap();
    let params_before = trainer.params.clone();

    // fresh trainer with a different seed -> different init; restore
    let opt2 = Box::new(EfSgd::new(
        Box::new(PowerSgd::new(2, 1)),
        LrSchedule::constant(0.05),
        0.9,
    ));
    let mut rt = Runtime::cpu(&dir).unwrap();
    let train = rt.load("mlp_train").unwrap();
    let eval = rt.load("mlp_eval").unwrap();
    let cfg = TrainerConfig { workers: 2, seed: 999, ..Default::default() };
    let mut restored = Trainer::new(train, Some(eval), opt2, cfg).unwrap();
    assert!(restored.params[0].max_abs_diff(&params_before[0]) > 1e-4);
    restored.load_checkpoint(&ckpt).unwrap();
    for (a, b) in restored.params.iter().zip(params_before.iter()) {
        assert_eq!(a, b);
    }
    std::fs::remove_file(ckpt).ok();
}
