//! Experiment registry + report generator integration suite
//! (DESIGN.md §12).
//!
//! Pins the three contracts the `powersgd experiment` subcommand rests
//! on:
//!
//! 1. **Snapshot determinism** — `REPORT.md` generation is
//!    byte-for-byte reproducible for a fixed seed after the
//!    `redact_measured` projection, which collapses only the
//!    `~`-marked wall-clock durations of the time-attribution section
//!    (and the file is therefore diffable across commits: the CI
//!    `experiment-smoke` job regenerates and diffs the redacted form on
//!    every push);
//! 2. **CLI round-trip** — every registered scenario's axes parse back
//!    through the CLI parsers (`scheme_by_name`, `profiles::by_name`,
//!    `backend_by_name`, `engine_by_name`), so nothing can be
//!    registered that a user could not also run by hand;
//! 3. **Measured == analytic** — the wire-check really executes the
//!    threaded engine and its measured byte counters equal the
//!    closed-form ring expansion on every rank.

use powersgd::experiments::{
    generate_report, measured_wire_check, redact_measured, registry, run_suite, scenarios_for,
    suite_by_name, wire_configs, write_report,
};
use powersgd::net::backend_by_name;
use powersgd::profiles;
use powersgd::simulate::scheme_by_name;
use powersgd::transport::engine_by_name;

#[test]
fn report_generation_is_byte_for_byte_deterministic() {
    let first = generate_report(42, /*quick=*/ false).expect("report generation");
    let second = generate_report(42, /*quick=*/ false).expect("report generation");
    // Wall-clock durations (and only those) are `~`-marked; everything
    // else — every analytic cell, byte count, and span count — must
    // reproduce byte-for-byte.
    assert_eq!(
        redact_measured(&first),
        redact_measured(&second),
        "REPORT.md must be byte-for-byte deterministic for a fixed seed (up to ~-durations)"
    );
    // Structure snapshot: every section and every profile present, and
    // the measured section verified.
    for needle in [
        "# PowerSGD experiment report",
        "## Rank sweep",
        "## Scheme compare",
        "## Worker scaling",
        "## Backend compare",
        "## Measured wire bytes (threaded engine)",
        "## Time attribution (traced threaded engine)",
        "ResNet18/CIFAR10",
        "LSTM/WikiText-2",
        "Transformer/WikiText-103",
        "Measured == analytic on every rank: **yes**",
        "sent matches the metered-transport total: **yes**",
        "worker-0, worker-1, worker-2, worker-3",
    ] {
        assert!(first.contains(needle), "report is missing {needle:?}");
    }
    // Value snapshot, hand-computed from the Appendix F shapes: rank-2
    // PowerSGD on ResNet18 transmits 329 512 bytes/step and SGD
    // 44 696 320 — the table rows must carry exactly these bytes.
    assert!(first.contains("| Rank 2 | 329512 |"), "rank-2 ResNet18 bytes row changed");
    assert!(first.contains("| SGD | 44696320 |"), "SGD ResNet18 bytes row changed");
}

#[test]
fn report_file_round_trips_through_write_report() {
    let dir = std::env::temp_dir().join(format!("powersgd-report-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = write_report(&dir, 42, /*quick=*/ true).expect("write_report");
    let on_disk = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        redact_measured(&on_disk),
        redact_measured(&generate_report(42, /*quick=*/ true).unwrap())
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn every_registered_scenario_round_trips_through_the_cli_parser() {
    for suite in registry() {
        assert_eq!(suite_by_name(suite.name).map(|s| s.name), Some(suite.name));
        for quick in [false, true] {
            for spec in scenarios_for(suite.name, quick) {
                let (name, rank) = spec.scheme.cli_spelling();
                assert_eq!(
                    scheme_by_name(&name, rank),
                    Some(spec.scheme),
                    "{}: scheme spelling {name:?} does not round-trip",
                    spec.id()
                );
                assert!(
                    profiles::by_name(spec.profile).is_some(),
                    "{}: unknown profile",
                    spec.id()
                );
                assert!(
                    backend_by_name(spec.backend).is_some(),
                    "{}: unknown backend",
                    spec.id()
                );
                assert!(engine_by_name(spec.engine).is_some(), "{}: unknown engine", spec.id());
            }
        }
    }
    // The measured configs must name real per-worker compressors.
    for quick in [false, true] {
        for cfg in wire_configs(quick) {
            assert!(
                powersgd::compress::worker_by_name(cfg.compressor, cfg.rank.max(1), 0).is_some(),
                "wire config {:?} has no per-worker implementation",
                cfg.compressor
            );
        }
    }
}

#[test]
fn measured_wire_bytes_match_analytic_on_the_threaded_ring() {
    let outcome = measured_wire_check("powersgd", 2, 2, 2, 7).expect("wire check");
    assert_eq!(outcome.per_rank.len(), 2);
    for r in &outcome.per_rank {
        assert_eq!(r.measured, r.analytic, "rank {}", r.rank);
        assert!(r.measured > 0, "rank {} sent nothing", r.rank);
        // Logical bytes follow the closed-form model exactly:
        // (12+8)·2·4 + 5·4 + (6+10)·2·4 + 3·4 = 320 bytes/step.
        assert_eq!(r.logical, 320 * 2, "rank {} logical bytes", r.rank);
    }
    assert_eq!(outcome.model_bytes_per_step, 320);
    // The traced capture saw one track per worker, its wire counters
    // agree with the metered transports, and both exposed-communication
    // figures exist (the analytic α/β price is deterministic and > 0).
    assert_eq!(outcome.spans.tracks, vec!["worker-0".to_string(), "worker-1".to_string()]);
    let metered_total: u64 = outcome.per_rank.iter().map(|r| r.measured).sum();
    assert_eq!(outcome.spans.wire_sent, metered_total);
    assert!(outcome.spans.count(powersgd::obs::Phase::Collective) > 0);
    assert!(outcome.analytic_exposed_s > 0.0);
    assert!(outcome.measured_recv_blocked_s() > 0.0);
}

#[test]
fn gather_scheme_wire_check_passes_too() {
    // Sign+Norm takes the all-gather path; its ring expansion is
    // (W−1)·msg per gather rather than the two-phase chunk schedule.
    let outcome = measured_wire_check("sign-norm", 0, 2, 2, 7).expect("wire check");
    for r in &outcome.per_rank {
        assert_eq!(r.measured, r.analytic, "rank {}", r.rank);
    }
}

#[test]
fn suite_runs_produce_artifacts_for_every_registered_suite() {
    let dir = std::env::temp_dir().join(format!("powersgd-suites-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for suite in registry() {
        let run = run_suite(suite.name, 42, /*quick=*/ true).expect(suite.name);
        assert!(!run.records.is_empty(), "{}: no records", suite.name);
        let doc = run.to_json();
        for (open, close) in [('{', '}'), ('[', ']')] {
            assert_eq!(
                doc.matches(open).count(),
                doc.matches(close).count(),
                "{}: unbalanced {open}{close}",
                suite.name
            );
        }
        let path = run.write_json(&dir).unwrap();
        assert_eq!(
            path.file_name().unwrap().to_str().unwrap(),
            format!("EXPERIMENTS_{}.json", suite.name)
        );
        assert!(path.exists());
    }
    std::fs::remove_dir_all(&dir).ok();
}
