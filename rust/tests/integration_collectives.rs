//! Integration: collectives + cost model against closed-form expectations.

use powersgd::collectives::{
    all_gather, all_reduce_mean, ring_all_reduce_sum, CollKind, CommLog,
};
use powersgd::net::{backend_by_name, GLOO, NCCL};
use powersgd::util::Rng;

#[test]
fn ring_all_reduce_large_buffers_many_workers() {
    let mut rng = Rng::new(31);
    for &w in &[2usize, 5, 16, 32] {
        let n = 10_007; // prime: chunk boundaries never align
        let bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut expect = vec![0.0f64; n];
        for b in &bufs {
            for (e, v) in expect.iter_mut().zip(b) {
                *e += *v as f64;
            }
        }
        let mut got = bufs.clone();
        ring_all_reduce_sum(&mut got);
        for b in &got {
            for (g, e) in b.iter().zip(&expect) {
                assert!((*g as f64 - e).abs() < 1e-3 * e.abs().max(1.0));
            }
        }
    }
}

#[test]
fn commlog_prices_consistently_across_backends() {
    let mut log = CommLog::default();
    let mut bufs = vec![vec![0.5f32; 1000]; 4];
    all_reduce_mean(&mut bufs, &mut log);
    let msgs = vec![vec![1.0f32; 250]; 4];
    let _ = all_gather(&msgs, &mut log);

    let t_nccl = NCCL.time_ops(&log.ops, 4);
    let t_gloo = GLOO.time_ops(&log.ops, 4);
    assert!(t_gloo > t_nccl);
    // decomposes to the two ops
    let t_parts = NCCL.time(CollKind::AllReduce, 4000, 4) + NCCL.time(CollKind::AllGather, 1000, 4);
    assert!((t_nccl - t_parts).abs() < 1e-12);
}

#[test]
fn allreduce_beats_gather_for_large_messages_many_workers() {
    // §3's O(log W) vs O(W) claim at the paper's gradient sizes.
    let bytes = 43_000_000;
    for &w in &[4usize, 8, 16, 32] {
        let red = NCCL.time(CollKind::AllReduce, bytes, w);
        let gat = NCCL.time(CollKind::AllGather, bytes, w);
        assert!(gat > red, "W={w}: gather {gat} must exceed reduce {red}");
    }
    // and the gap widens with W
    let gap8 = NCCL.time(CollKind::AllGather, bytes, 8) / NCCL.time(CollKind::AllReduce, bytes, 8);
    let gap32 =
        NCCL.time(CollKind::AllGather, bytes, 32) / NCCL.time(CollKind::AllReduce, bytes, 32);
    assert!(gap32 > gap8);
}

#[test]
fn backend_lookup_and_appendix_b_ordering() {
    let nccl = backend_by_name("nccl").unwrap();
    let gloo = backend_by_name("gloo").unwrap();
    // Appendix B: GLOO collectives are slower at every size measured.
    for bytes in [1_000u64, 100_000, 10_000_000, 100_000_000] {
        for kind in [CollKind::AllReduce, CollKind::AllGather, CollKind::ReduceBroadcast] {
            assert!(gloo.time(kind, bytes, 16) > nccl.time(kind, bytes, 16));
        }
    }
}

#[test]
fn parameter_server_double_cost() {
    // §3: PS "double compression" — reduce+broadcast costs ≈ 2× the
    // one-way volume; at large sizes PS ≥ all-reduce.
    let bytes = 10_000_000;
    let ps = NCCL.time(CollKind::ReduceBroadcast, bytes, 16);
    let ar = NCCL.time(CollKind::AllReduce, bytes, 16);
    assert!(ps > ar);
}
