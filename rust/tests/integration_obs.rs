//! Observability integration suite (DESIGN.md §13).
//!
//! Pins the three contracts of the `obs` span recorder:
//!
//! 1. **No perturbation** — a fully traced run (timing + trace mode
//!    both on) produces bitwise-identical results to an untraced run,
//!    on both transport engines and at 1 and 4 kernel-pool threads;
//! 2. **Valid export** — a capture of a real compression round
//!    serializes to well-formed Chrome-trace JSON (balanced B/E pairs,
//!    monotone per-track timestamps), and per-rank documents merge —
//!    including the partial, dead-peer merge — without losing validity;
//! 3. **Deterministic summary** — two captures of the same workload
//!    agree exactly on the deterministic projection (per-phase span
//!    counts, track names, wire bytes); only wall-clock durations may
//!    differ.

use powersgd::collectives::CommLog;
use powersgd::compress::{Compressor, PowerSgd};
use powersgd::obs::{self, chrome, Phase};
use powersgd::runtime::pool::set_threads;
use powersgd::tensor::Tensor;
use powersgd::transport::EngineKind;
use powersgd::util::Rng;
use std::sync::Mutex;

/// Every test here flips process-wide state (obs mode bits, the
/// kernel-pool width); one lock serializes the whole binary so no test
/// observes another's configuration. (Engine selection is per-run
/// configuration — `CommLog::on` — and needs no serialization.)
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn obs_guard() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Per-worker gradient-shaped updates (two matrices plus a bias
/// vector), freshly seeded per call so consecutive steps differ.
fn worker_updates(seed: u64, workers: usize) -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(seed);
    (0..workers)
        .map(|_| {
            [&[24usize, 16][..], &[7], &[9, 11]]
                .into_iter()
                .map(|shape| {
                    let mut t = Tensor::zeros(shape);
                    rng.fill_normal(t.data_mut(), 1.0);
                    t
                })
                .collect()
        })
        .collect()
}

/// Three full centralized PowerSGD rounds (rank 2, warm-started factor
/// memory) over 4 workers on `engine`; returns the final aggregated
/// mean.
fn powersgd_rounds(engine: EngineKind) -> Vec<Tensor> {
    let mut comp = PowerSgd::new(2, 1);
    let mut mean = Vec::new();
    for step in 0..3u64 {
        let mut log = CommLog::on(engine);
        mean = comp.compress_aggregate(&worker_updates(900 + step, 4), &mut log).mean;
    }
    mean
}

/// Contract 1: tracing must never perturb computed values. The same
/// seeded workload runs untraced and under a full capture, on every
/// engine × thread-count combination, and every result must be
/// bit-identical — to its untraced twin and across configurations
/// (kernels are bitwise-deterministic at any thread count, DESIGN.md
/// §11, so one reference covers all eight runs).
#[test]
fn traced_run_is_bitwise_identical_to_untraced() {
    let _g = obs_guard();
    let mut results: Vec<(String, Vec<Tensor>)> = Vec::new();
    for engine in [EngineKind::Lockstep, EngineKind::Threaded] {
        for threads in [1usize, 4] {
            set_threads(threads);
            let untraced = powersgd_rounds(engine);
            let (traced, _cap) = obs::capture(|| powersgd_rounds(engine));
            set_threads(1);
            assert_eq!(
                traced, untraced,
                "tracing perturbed the result ({engine:?}, {threads} threads)"
            );
            results.push((format!("{engine:?} x {threads} threads"), untraced));
        }
    }
    let (first_label, first) = &results[0];
    for (label, r) in &results[1..] {
        assert_eq!(r, first, "{label} diverged from {first_label}");
    }
}

/// Contract 2: a capture of a real threaded-engine compression round
/// exports to valid Chrome-trace JSON, and the coordinator-side merge
/// (full and dead-peer partial) preserves validity.
#[test]
fn captured_compression_round_exports_valid_chrome_trace() {
    let _g = obs_guard();
    let (_, cap) = obs::capture(|| {
        obs::set_track("worker-0");
        let mut comp = PowerSgd::new(2, 1);
        let mut log = CommLog::on(EngineKind::Threaded);
        std::hint::black_box(comp.compress_aggregate(&worker_updates(17, 4), &mut log));
    });

    // The round really hit the kernels and the ring.
    let all = cap.summary(&[]);
    assert!(all.count(Phase::MatmulNn) > 0, "no NN GEMM spans");
    assert!(all.count(Phase::GramSchmidt) > 0, "no Gram-Schmidt spans");
    assert!(all.count(Phase::Collective) > 0, "no collective spans");

    let part0 = chrome::chrome_trace_json(0, "worker rank 0", &cap.tracks);
    let pairs = chrome::validate_chrome_trace(&part0).expect("per-rank trace well-formed");
    assert!(pairs > 0, "trace carried no spans");
    assert!(part0.contains("\"thread_name\""), "tracks must be named");

    // Merge two per-rank parts, then only one (a dead peer's file is
    // simply absent) — both stay structurally valid.
    let part1 = chrome::chrome_trace_json(1, "worker rank 1", &cap.tracks);
    let merged = chrome::merge_chrome_traces(&[part0.clone(), part1]).expect("merge");
    assert_eq!(chrome::validate_chrome_trace(&merged).expect("merged valid"), 2 * pairs);
    assert!(merged.contains("\"pid\": 0") && merged.contains("\"pid\": 1"));
    let partial = chrome::merge_chrome_traces(&[part0]).expect("partial merge");
    assert_eq!(chrome::validate_chrome_trace(&partial).expect("partial valid"), pairs);
}

/// Contract 3: two captures of the same seeded workload agree exactly
/// on the deterministic projection — per-phase span counts, sorted
/// track names, wire-byte counters — while durations are free to vary.
#[test]
fn capture_summary_is_deterministic_for_a_fixed_workload() {
    let _g = obs_guard();
    let run = || {
        set_threads(1);
        let (_, cap) = obs::capture(|| {
            obs::set_track("worker-0");
            let mut comp = PowerSgd::new(2, 1);
            let mut log = CommLog::on(EngineKind::Threaded);
            std::hint::black_box(comp.compress_aggregate(&worker_updates(23, 4), &mut log));
        });
        // `worker-` catches the compressing thread, `ring-` the
        // threaded collective threads; the prefix filter drops any
        // track a concurrent non-workload thread might record.
        cap.summary(&["worker-", "ring-"])
    };
    let first = run();
    let second = run();
    assert_eq!(
        first.deterministic_key(),
        second.deterministic_key(),
        "span counts / tracks / wire bytes must reproduce exactly"
    );
    assert!(first.tracks.contains(&"worker-0".to_string()), "tracks: {:?}", first.tracks);
    assert!(first.count(Phase::RingSend) > 0, "threaded ring recorded no send spans");
    assert!(first.count(Phase::RingRecv) > 0, "threaded ring recorded no recv spans");
    // Modes were off before the captures and must be off after them.
    assert_eq!(obs::mode(), 0, "capture leaked an enabled mode");
}
