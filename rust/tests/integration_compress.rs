//! Integration: the full compressor matrix driving EF-SGD on a common
//! synthetic objective, checking convergence behaviour, byte accounting
//! and aggregation-kind claims across all nine operators (paper Table 4).

use powersgd::collectives::CommLog;
use powersgd::compress::*;
use powersgd::grad::ParamRegistry;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule};
use powersgd::tensor::Tensor;
use powersgd::util::Rng;

fn registry() -> ParamRegistry {
    ParamRegistry::from_shapes(&[("w", vec![24, 16]), ("b", vec![8])])
}

fn quad_grads(x: &[Tensor], w: usize, noise: f32, rng: &mut Rng) -> Vec<Vec<Tensor>> {
    (0..w)
        .map(|_| {
            x.iter()
                .map(|t| {
                    let mut g = t.clone();
                    let mut nz = Tensor::zeros(t.shape());
                    rng.fill_normal(nz.data_mut(), noise);
                    g.axpy(1.0, &nz);
                    g
                })
                .collect()
        })
        .collect()
}

fn train_quadratic(mut opt: Box<dyn DistOptimizer>, steps: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut x = vec![Tensor::full(&[24, 16], 1.0), Tensor::full(&[8], -1.0)];
    let mut log = CommLog::default();
    for step in 0..steps {
        let grads = quad_grads(&x, 4, 0.02, &mut rng);
        let delta = opt.step(&grads, step, &mut log);
        for (xi, di) in x.iter_mut().zip(delta.iter()) {
            xi.axpy(-1.0, di);
        }
    }
    x.iter().map(|t| t.norm()).sum()
}

fn all_compressors(seed: u64) -> Vec<Box<dyn Compressor>> {
    vec![
        Box::new(NoCompression::new()),
        Box::new(PowerSgd::new(2, seed)),
        Box::new(PowerSgd::new(2, seed).without_warm_start()),
        Box::new(BestRankR::new(2, seed)),
        Box::new(UnbiasedRank::new(2, seed)),
        Box::new(RandomBlock::new(2, seed)),
        Box::new(RandomK::new(2, seed)),
        Box::new(TopK::new(2)),
        Box::new(SignNorm::new()),
        Box::new(Signum::new()),
        Box::new(Atomo::new(2, seed)),
    ]
}

#[test]
fn every_biased_compressor_with_ef_converges_on_quadratic() {
    // Signum's ±1 output cannot settle on a quadratic with plain EF-SGD
    // (it has its own optimizer), and the high-variance Unbiased Rank
    // scheme diverges under heavy momentum — exactly the pathology
    // Table 1 documents (71.2% vs 93.6% test accuracy). Both are
    // exercised in their paper-faithful configurations elsewhere.
    for comp in all_compressors(7) {
        let name = comp.name();
        if name == "Signum" || name.starts_with("Unbiased") || name.starts_with("Atomo") {
            // Atomo is likewise unbiased and run without EF in the paper
            // (Appendix G.6, its own tuned learning rate).
            continue;
        }
        let opt = Box::new(EfSgd::new(comp, LrSchedule::constant(0.02), 0.5));
        let final_norm = train_quadratic(opt, 800, 11);
        assert!(final_norm < 0.5, "{name} failed to converge: |x| = {final_norm}");
    }
}

#[test]
fn byte_accounting_matches_closed_form_for_all() {
    let reg = registry();
    let mut rng = Rng::new(13);
    let updates: Vec<Vec<Tensor>> = (0..3)
        .map(|_| {
            vec![
                {
                    let mut t = Tensor::zeros(&[24, 16]);
                    rng.fill_normal(t.data_mut(), 1.0);
                    t
                },
                {
                    let mut t = Tensor::zeros(&[8]);
                    rng.fill_normal(t.data_mut(), 1.0);
                    t
                },
            ]
        })
        .collect();
    for mut comp in all_compressors(17) {
        let mut log = CommLog::default();
        comp.compress_aggregate(&updates, &mut log);
        assert_eq!(
            log.bytes_sent(),
            comp.message_bytes(&reg),
            "byte mismatch for {}",
            comp.name()
        );
    }
}

#[test]
fn aggregation_kind_matches_table4() {
    // Table 4's "All-reduce" column.
    let yes = ["No compression", "Rank 2", "Unbiased Rank 2"];
    for comp in all_compressors(19) {
        let name = comp.name();
        let expect = yes.iter().any(|y| name.starts_with(y))
            || name.starts_with("Random")
            || name.starts_with("Best rank");
        assert_eq!(comp.supports_all_reduce(), expect, "{name}");
    }
}

#[test]
fn compression_ratios_match_paper_scale() {
    // Rank-r PowerSGD on the ResNet18 profile compresses > 100× (paper:
    // 243/r ×); sign-based ≈ 32×.
    let p = powersgd::profiles::resnet18();
    let full = p.registry.total_bytes() as f64;
    let r2 = PowerSgd::new(2, 0).message_bytes(&p.registry) as f64;
    assert!(full / r2 > 100.0, "rank-2 ratio {}", full / r2);
    let sign = SignNorm::new().message_bytes(&p.registry) as f64;
    let ratio = full / sign;
    assert!((25.0..35.0).contains(&ratio), "sign ratio {ratio}");
}

#[test]
fn warm_start_beats_cold_on_slow_moving_objective() {
    // Table 2's mechanism: on a slowly-varying gradient sequence the
    // warm-started approximation tracks the dominant subspace better.
    let mut rng = Rng::new(23);
    let mut base = Tensor::zeros(&[30, 20]);
    rng.fill_normal(base.data_mut(), 1.0);

    let mut warm = PowerSgd::new(1, 5);
    let mut cold = PowerSgd::new(1, 5).without_warm_start();
    let mut log = CommLog::default();
    let (mut err_warm, mut err_cold) = (0.0, 0.0);
    for _ in 0..30 {
        // slow drift
        let mut drift = Tensor::zeros(&[30, 20]);
        rng.fill_normal(drift.data_mut(), 0.02);
        base.axpy(1.0, &drift);
        let updates = vec![vec![base.clone()]];
        err_warm += base.sub(&warm.compress_aggregate(&updates, &mut log).mean[0]).norm();
        err_cold += base.sub(&cold.compress_aggregate(&updates, &mut log).mean[0]).norm();
    }
    assert!(
        err_warm < err_cold,
        "warm {err_warm} should beat cold {err_cold}"
    );
}
