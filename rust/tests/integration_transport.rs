//! Integration: the threaded transport engine against the lockstep
//! oracle — property sweeps over random worker counts and buffer
//! lengths, end-to-end trajectory determinism, and the overlap
//! scheduler's acceptance shape.

use powersgd::collectives::{all_gather, all_reduce_mean, ring_all_reduce_sum, CommLog};
use powersgd::compress::PowerSgd;
use powersgd::net::NCCL;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule};
use powersgd::profiles::resnet18;
use powersgd::simulate::{simulate_step_overlapped, Scheme};
use powersgd::tensor::Tensor;
use powersgd::transport::{
    ring_all_gather_threaded, ring_all_reduce_sum_threaded, Bucketer, Cluster, EngineKind,
    LayerTiming,
};
use powersgd::util::Rng;

/// Property: threaded ring all-reduce matches the naive sum within
/// float-associativity tolerance, over random worker counts and buffer
/// lengths (proptest-style seeded sweep; no proptest crate offline).
#[test]
fn prop_threaded_ring_matches_naive_sum() {
    let mut rng = Rng::new(71);
    for case in 0..40 {
        let w = 1 + rng.below(17) as usize;
        let n = rng.below(2000) as usize;
        let bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut expect = vec![0.0f64; n];
        for b in &bufs {
            for (e, v) in expect.iter_mut().zip(b) {
                *e += *v as f64;
            }
        }
        let mut got = bufs.clone();
        ring_all_reduce_sum_threaded(&mut got);
        for b in &got {
            for (g, e) in b.iter().zip(&expect) {
                assert!(
                    (*g as f64 - e).abs() <= 1e-3 * e.abs().max(1.0),
                    "case {case} w={w} n={n}: {g} vs {e}"
                );
            }
        }
    }
}

/// Property: the threaded engine reproduces the lockstep engine
/// *bitwise* — same chunk schedule, same accumulation order.
#[test]
fn prop_threaded_engine_is_bitwise_identical_to_lockstep() {
    let mut rng = Rng::new(72);
    for _ in 0..25 {
        let w = 1 + rng.below(12) as usize;
        let n = rng.below(1500) as usize;
        let bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();

        let mut lockstep = bufs.clone();
        ring_all_reduce_sum(&mut lockstep);

        let mut threaded = bufs.clone();
        ring_all_reduce_sum_threaded(&mut threaded);

        assert_eq!(threaded, lockstep, "w={w} n={n}");
    }
}

#[test]
fn threaded_all_gather_matches_lockstep_view() {
    let mut rng = Rng::new(73);
    let msgs: Vec<Vec<f32>> = (0..6)
        .map(|_| (0..37).map(|_| rng.normal() as f32).collect())
        .collect();
    let view = ring_all_gather_threaded(&msgs);
    assert_eq!(view, msgs);

    // Through the public collective, on a threaded-engine log.
    let mut log = CommLog::on(EngineKind::Threaded);
    let gathered = all_gather(&msgs, &mut log);
    assert_eq!(gathered.len(), 6);
    assert_eq!(*gathered[3], msgs);
    assert_eq!(log.bytes_sent(), 37 * 4);
}

/// Determinism acceptance: the threaded engine yields the *same training
/// trajectory* as lockstep for a fixed seed (EF-SGD + PowerSGD over a
/// noisy quadratic — the full optimizer stack minus PJRT).
#[test]
fn threaded_training_trajectory_equals_lockstep() {
    let run = |engine: EngineKind| -> Vec<Tensor> {
        let mut rng = Rng::new(301);
        let mut x = vec![Tensor::full(&[12, 9], 1.0), Tensor::full(&[7], -1.5)];
        let mut opt = EfSgd::new(Box::new(PowerSgd::new(2, 5)), LrSchedule::constant(0.05), 0.9);
        let mut log = CommLog::on(engine);
        for step in 0..60 {
            // gradient of ||x||²/2 plus per-worker noise
            let grads: Vec<Vec<Tensor>> = (0..4)
                .map(|_| {
                    x.iter()
                        .map(|t| {
                            let mut g = t.clone();
                            let mut nz = Tensor::zeros(t.shape());
                            rng.fill_normal(nz.data_mut(), 0.01);
                            g.axpy(1.0, &nz);
                            g
                        })
                        .collect()
                })
                .collect();
            let delta = opt.step(&grads, step, &mut log);
            for (xi, di) in x.iter_mut().zip(delta.iter()) {
                xi.axpy(-1.0, di);
            }
        }
        x
    };
    let lockstep = run(EngineKind::Lockstep);
    let threaded = run(EngineKind::Threaded);
    for (a, b) in lockstep.iter().zip(threaded.iter()) {
        assert_eq!(a, b, "trajectories must match exactly");
    }
}

#[test]
fn empty_collectives_do_not_panic() {
    // Regression: `buffers[0]` used to panic on an empty worker set.
    let mut log = CommLog::default();
    let mut empty: Vec<Vec<f32>> = Vec::new();
    all_reduce_mean(&mut empty, &mut log);
    ring_all_reduce_sum(&mut empty);
    assert!(all_gather(&[], &mut log).is_empty());
    assert_eq!(log.bytes_sent(), 0);
}

#[test]
fn bucketer_covers_resnet_layers() {
    let prof = resnet18();
    let scheme = Scheme::PowerSgd { rank: 2 };
    let layers: Vec<LayerTiming> = scheme.layer_timings(&prof.registry);
    let buckets = Bucketer::from_mb(4.0).assign(&layers);
    assert!(buckets.len() > 3, "43 MB of gradients should span many 4 MB buckets");
    let covered: u64 = buckets.iter().map(|b| b.raw_bytes).sum();
    assert_eq!(covered, prof.registry.total_bytes());
    let msg: u64 = buckets.iter().map(|b| b.msg_bytes).sum();
    assert_eq!(msg, scheme.message_bytes(&prof.registry));
}

/// Acceptance: bucketing + overlap strictly below the no-overlap
/// configuration for PowerSGD rank 2 at W ∈ {4, 8, 16}.
#[test]
fn overlap_acceptance_powersgd_rank2() {
    let prof = resnet18();
    for &w in &[4usize, 8, 16] {
        let cluster = Cluster::uniform(w, &NCCL);
        let scheme = Scheme::PowerSgd { rank: 2 };
        let ovl = simulate_step_overlapped(&prof, scheme, &cluster, 4 << 20, true);
        let seq = simulate_step_overlapped(&prof, scheme, &cluster, 4 << 20, false);
        assert!(
            ovl.total < seq.total,
            "W={w}: {:.2} ms !< {:.2} ms",
            ovl.total * 1e3,
            seq.total * 1e3
        );
    }
}
