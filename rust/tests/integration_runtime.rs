//! Integration: PJRT runtime over real artifacts, including the
//! Rust-native vs XLA-Pallas differential test for PowerSGD.
//!
//! Requires `make artifacts` (skips gracefully otherwise).

use powersgd::linalg::gram_schmidt_in_place;
use powersgd::runtime::{Runtime, Value};
use powersgd::tensor::{matmul, matmul_at_b, Tensor};
use powersgd::util::Rng;

fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("mlp_train.manifest").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

#[test]
fn mlp_train_artifact_runs_and_matches_manifest() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    let art = rt.load("mlp_train").unwrap();
    let m = &art.manifest;
    assert_eq!(m.params.len(), 4);
    let mut rng = Rng::new(41);
    let mut inputs: Vec<Value> = Vec::new();
    for spec in &m.inputs {
        match spec.dtype {
            powersgd::runtime::DType::F32 => {
                inputs.push(Value::F32(rand_tensor(&spec.shape, &mut rng)))
            }
            powersgd::runtime::DType::I32 => {
                let n: usize = spec.shape.iter().product();
                inputs.push(Value::I32(
                    spec.shape.clone(),
                    (0..n).map(|i| (i % 10) as i32).collect(),
                ));
            }
        }
    }
    let outs = art.execute(&inputs).unwrap();
    assert_eq!(outs.len(), m.outputs.len());
    // grads have param shapes
    for (g, p) in outs[1..].iter().zip(m.param_specs()) {
        assert_eq!(g.shape(), &p.shape[..]);
    }
    assert!(outs[0].data()[0].is_finite());
}

#[test]
fn artifact_shape_validation_rejects_bad_input() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    let art = rt.load("mlp_train").unwrap();
    let bad = vec![Value::F32(Tensor::zeros(&[1]))];
    assert!(art.execute(&bad).is_err());
}

#[test]
fn runtime_caches_compiled_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::cpu(&dir).unwrap();
    let a = rt.load("mlp_eval").unwrap();
    let b = rt.load("mlp_eval").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    assert!(rt.available().contains(&"mlp_train".to_string()));
}

/// Differential test: the XLA/Pallas compression artifacts must agree
/// with the Rust-native PowerSGD math on the same inputs.
#[test]
fn pallas_artifacts_match_native_powersgd() {
    let Some(dir) = artifacts_dir() else { return };
    if !std::path::Path::new(&dir).join("powersgd_stage1_16x10_r2.manifest").exists() {
        eprintln!("SKIP: powersgd kernel artifacts not built");
        return;
    }
    let mut rt = Runtime::cpu(&dir).unwrap();
    let (n, m, r) = (16usize, 10usize, 2usize);
    let mut rng = Rng::new(43);
    let m_mat = rand_tensor(&[n, m], &mut rng);
    let q0 = rand_tensor(&[m, r], &mut rng);

    // stage 1: P = M·Q
    let s1 = rt.load("powersgd_stage1_16x10_r2").unwrap();
    let p_xla = &s1.execute(&[m_mat.clone().into(), q0.clone().into()]).unwrap()[0];
    let p_native = matmul(&m_mat, &q0);
    assert!(
        p_xla.allclose(&p_native, 1e-4, 1e-4),
        "stage1 diff {}",
        p_xla.max_abs_diff(&p_native)
    );

    // stage 2: P̂ = GS(P); Q = Mᵀ·P̂
    let s2 = rt.load("powersgd_stage2_16x10_r2").unwrap();
    let outs = s2.execute(&[m_mat.clone().into(), p_native.clone().into()]).unwrap();
    let mut p_hat_native = p_native.clone();
    gram_schmidt_in_place(&mut p_hat_native);
    // Gram–Schmidt sign conventions agree (both normalize without flips).
    assert!(
        outs[0].allclose(&p_hat_native, 2e-3, 2e-3),
        "p_hat diff {}",
        outs[0].max_abs_diff(&p_hat_native)
    );
    let q_native = matmul_at_b(&m_mat, &p_hat_native);
    assert!(
        outs[1].allclose(&q_native, 2e-3, 2e-3),
        "q diff {}",
        outs[1].max_abs_diff(&q_native)
    );

    // decompress: M̂ = P̂Qᵀ; e = Δ − M̂
    let dec = rt.load("powersgd_decompress_16x10_r2").unwrap();
    let outs = dec
        .execute(&[
            p_hat_native.clone().into(),
            q_native.clone().into(),
            m_mat.clone().into(),
        ])
        .unwrap();
    let m_hat_native = matmul(&p_hat_native, &q_native.transpose());
    let err_native = m_mat.sub(&m_hat_native);
    assert!(outs[0].allclose(&m_hat_native, 1e-3, 1e-3));
    assert!(outs[1].allclose(&err_native, 1e-3, 1e-3));
}
