//! Integration: elastic epoch-based ring membership (DESIGN.md §16).
//!
//! Every test drives the real elastic harness in-process — worker
//! threads running [`run_worker_elastic`] over real localhost sockets
//! against a [`coordinate_elastic`] call — with deterministic fault
//! injection instead of wall-clock-dependent kills:
//!
//! - a worker crashing at a step **boundary** re-forms the ring and the
//!   run finishes at `W−1`, bitwise-equal to the composed elastic
//!   oracle;
//! - a worker crashing **mid-step** (ring collectives in flight) makes
//!   the survivors roll the step back, re-form, and re-run it;
//! - a **late joiner** is admitted at a step boundary and the run
//!   finishes at `W+1`;
//! - under **stable membership**, `--elastic` is bitwise-identical to
//!   the non-elastic lockstep oracle (the heartbeat barrier must not
//!   perturb a single computed bit).
//!
//! The multi-process variant of the boundary-crash scenario runs in CI
//! as the `churn-smoke` job (`launch --elastic --fail-rank …`).

use powersgd::transport::tcp::{
    coordinate_elastic, elastic_oracle_trajectory, oracle_trajectory, run_worker_elastic,
    EpochPlan, HarnessConfig, LaunchOutcome, Rendezvous,
};
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

/// Spawn `spawn` elastic worker threads against a coordinator expecting
/// `world` initial members (spawn > world leaves the extras as late
/// joiners), and return the coordinator outcome plus every worker
/// thread's result.
fn run_elastic_ring(
    world: usize,
    spawn: usize,
    cfg: &HarnessConfig,
    join_at_step: Option<u64>,
) -> (anyhow::Result<LaunchOutcome>, Vec<anyhow::Result<usize>>) {
    let rendezvous = Rendezvous::bind("127.0.0.1:0").expect("bind rendezvous");
    let addr = rendezvous.addr().expect("rendezvous addr");
    let workers: Vec<_> = (0..spawn)
        .map(|_| {
            let addr = addr.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || {
                run_worker_elastic(&addr, &cfg, TIMEOUT).map(|(rank, _)| rank)
            })
        })
        .collect();
    let outcome = coordinate_elastic(&rendezvous, world, cfg, TIMEOUT, join_at_step);
    let results = workers
        .into_iter()
        .map(|h| h.join().expect("worker thread panicked"))
        .collect();
    (outcome, results)
}

/// Split worker results into (survivor ranks, injected-crash errors),
/// panicking on any error that is *not* the deliberate fault injection.
fn split_survivors(results: Vec<anyhow::Result<usize>>) -> (Vec<usize>, usize) {
    let mut survivors = Vec::new();
    let mut crashed = 0usize;
    for (idx, r) in results.into_iter().enumerate() {
        match r {
            Ok(rank) => survivors.push(rank),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(msg.contains("fault injection"), "worker #{idx} died unexpectedly: {msg}");
                crashed += 1;
            }
        }
    }
    survivors.sort_unstable();
    (survivors, crashed)
}

/// Tentpole acceptance: rank 1 of a 3-worker elastic run crashes at the
/// step-1 boundary; the survivors re-form at `W=2` and finish all 4
/// steps bitwise-equal to the composed elastic oracle (the coordinator
/// bails otherwise, so `Ok` is the equivalence assertion). The epoch
/// history records the transition and the departed rank.
#[test]
fn boundary_crash_reforms_and_continues_at_w_minus_1() {
    let cfg = HarnessConfig {
        elastic: true,
        steps: 4,
        fail_rank: Some(1),
        fail_at_step: 1,
        ..HarnessConfig::default()
    };
    let (outcome, results) = run_elastic_ring(3, 3, &cfg, None);
    let (survivors, crashed) = split_survivors(results);
    assert_eq!(crashed, 1, "exactly the injected rank must crash");
    assert_eq!(survivors, vec![0, 2], "survivors keep their epoch-0 identities");
    let outcome = outcome.unwrap_or_else(|e| panic!("coordinate_elastic: {e:#}"));
    assert_eq!(outcome.reports.len(), 2);
    assert!(outcome.reports.iter().all(|r| r.bitwise));
    assert!(outcome.oracle_verified, "boundary crashes verify against the composed oracle");
    assert_eq!(outcome.epochs.len(), 2, "one re-formation");
    assert_eq!(outcome.epochs[1].world, 2);
    assert_eq!(outcome.epochs[1].start_step, 1);
    assert_eq!(outcome.epochs[1].missing_ranks, vec![1]);
    assert_eq!(outcome.epochs[1].joined, 0);
}

/// Mid-step crash: the injected rank dies *after* the barrier releases,
/// with ring collectives in flight. The survivors' collectives panic,
/// they roll the logical log back to the step boundary, re-form, and
/// re-run the same step — still bitwise-equal to the composed oracle
/// (PowerSGD's per-step execution is replay-safe: warm `Q` commits only
/// after a successful step).
#[test]
fn midstep_crash_rolls_back_and_rerun_stays_bitwise() {
    let cfg = HarnessConfig {
        elastic: true,
        steps: 3,
        fail_rank: Some(1),
        fail_at_step: 1,
        fail_midstep: true,
        ..HarnessConfig::default()
    };
    let (outcome, results) = run_elastic_ring(3, 3, &cfg, None);
    let (survivors, crashed) = split_survivors(results);
    assert_eq!(crashed, 1);
    assert_eq!(survivors, vec![0, 2]);
    let outcome = outcome.unwrap_or_else(|e| panic!("coordinate_elastic: {e:#}"));
    assert!(outcome.reports.iter().all(|r| r.bitwise));
    assert_eq!(outcome.epochs.len(), 2);
    // The aborted attempt is re-run under the new epoch, so the epoch
    // still begins at the crashed step, not the one after it.
    assert_eq!(outcome.epochs[1].start_step, 1);
    assert_eq!(outcome.epochs[1].world, 2);
}

/// A 2-worker elastic run degenerating to a single survivor: the
/// re-formed "ring" of one loops through the worker's own listener and
/// the run still finishes, verified against the composed oracle at
/// `W=1`.
#[test]
fn crash_to_single_worker_still_finishes() {
    let cfg = HarnessConfig {
        elastic: true,
        steps: 3,
        fail_rank: Some(1),
        fail_at_step: 1,
        ..HarnessConfig::default()
    };
    let (outcome, results) = run_elastic_ring(2, 2, &cfg, None);
    let (survivors, crashed) = split_survivors(results);
    assert_eq!(crashed, 1);
    assert_eq!(survivors, vec![0]);
    let outcome = outcome.unwrap_or_else(|e| panic!("coordinate_elastic: {e:#}"));
    assert_eq!(outcome.reports.len(), 1);
    assert!(outcome.reports[0].bitwise);
    assert_eq!(outcome.epochs[1].world, 1);
}

/// Late join: a third identical worker is spawned up front, its `Hello`
/// held in the coordinator's backlog, and it is admitted at the step-1
/// boundary. With a stateless scheme (sign-norm) the joiner's fresh
/// compressor equals a survivor's, so the whole `W=2 → W=3` run is
/// verified bitwise against the composed elastic oracle.
#[test]
fn late_joiner_is_admitted_and_run_finishes_at_w_plus_1() {
    let cfg = HarnessConfig {
        elastic: true,
        compressor: "sign-norm".into(),
        steps: 3,
        ..HarnessConfig::default()
    };
    let (outcome, results) = run_elastic_ring(2, 3, &cfg, Some(1));
    let (survivors, crashed) = split_survivors(results);
    assert_eq!(crashed, 0);
    assert_eq!(survivors, vec![0, 1, 2], "the joiner gets the next origin id");
    let outcome = outcome.unwrap_or_else(|e| panic!("coordinate_elastic: {e:#}"));
    assert_eq!(outcome.reports.len(), 3);
    assert!(outcome.reports.iter().all(|r| r.bitwise));
    assert!(outcome.oracle_verified, "stateless joins stay oracle-verifiable");
    assert_eq!(outcome.epochs.len(), 2);
    assert_eq!(outcome.epochs[1].world, 3);
    assert_eq!(outcome.epochs[1].start_step, 1);
    assert_eq!(outcome.epochs[1].joined, 1);
    assert!(outcome.epochs[1].missing_ranks.is_empty());
    // The joiner executed two of the three steps; its logical bytes
    // reflect that, per the member-wise accounting.
    let joiner = outcome.reports.iter().find(|r| r.rank == 2).unwrap();
    assert_eq!(joiner.logical_bytes, outcome.model_bytes_per_step * 2);
}

/// Late join with a *stateful* scheme (PowerSGD): the joiner's fresh
/// warm-start `Q` differs from the survivors', so bitwise-vs-oracle is
/// out of reach — but every member must still agree with every other
/// (the aggregate is shared), which is exactly what the coordinator's
/// member-consistency fallback verifies.
#[test]
fn late_joiner_with_stateful_scheme_is_member_consistent() {
    let cfg = HarnessConfig { elastic: true, steps: 4, ..HarnessConfig::default() };
    let (outcome, results) = run_elastic_ring(2, 3, &cfg, Some(2));
    let (survivors, crashed) = split_survivors(results);
    assert_eq!(crashed, 0);
    assert_eq!(survivors, vec![0, 1, 2]);
    let outcome = outcome.unwrap_or_else(|e| panic!("coordinate_elastic: {e:#}"));
    assert_eq!(outcome.reports.len(), 3);
    assert!(outcome.reports.iter().all(|r| r.bitwise), "members diverged from each other");
    assert!(!outcome.oracle_verified, "a stateful join must fall back to member-consistency");
    assert_eq!(outcome.epochs[1].joined, 1);
}

/// Determinism acceptance: under stable membership the elastic machinery
/// (heartbeat barrier, epoch accounting) must not perturb a single bit —
/// the coordinator verifies every member against the composed oracle,
/// which this test additionally pins to the plain non-elastic oracle.
#[test]
fn stable_membership_elastic_run_is_bitwise_equal_to_lockstep_oracle() {
    for world in [2usize, 4] {
        let cfg = HarnessConfig { elastic: true, steps: 3, seed: 17, ..HarnessConfig::default() };
        let (outcome, results) = run_elastic_ring(world, world, &cfg, None);
        let (survivors, crashed) = split_survivors(results);
        assert_eq!(crashed, 0, "w={world}");
        assert_eq!(survivors.len(), world, "w={world}");
        let outcome = outcome.unwrap_or_else(|e| panic!("w={world} coordinate_elastic: {e:#}"));
        assert_eq!(outcome.reports.len(), world);
        assert!(outcome.reports.iter().all(|r| r.bitwise), "w={world}");
        assert_eq!(outcome.epochs.len(), 1, "w={world}: no re-formation may happen");
        // The composed oracle over a single stable epoch *is* the
        // non-elastic lockstep oracle, parameters and logical bytes.
        let plans =
            [EpochPlan { world, start_step: 0, departed_slots: Vec::new(), joined: 0 }];
        let (composed, composed_bytes) = elastic_oracle_trajectory(&cfg, &plans).unwrap();
        let (plain, plain_bytes) = oracle_trajectory(world, &cfg).unwrap();
        assert_eq!(composed_bytes, plain_bytes, "w={world}");
        for (a, b) in composed.iter().zip(plain.iter()) {
            assert_eq!(a.data(), b.data(), "w={world}: composed oracle drifted");
        }
        assert_eq!(outcome.logical_bytes, plain_bytes, "w={world}");
    }
}

/// The composed elastic oracle applied to a crash schedule differs from
/// the full-world oracle (the departed worker's gradients stop
/// contributing) but matches a fresh replay of itself — determinism of
/// the reference itself, which all crash tests lean on.
#[test]
fn composed_elastic_oracle_is_deterministic_and_world_sensitive() {
    let cfg = HarnessConfig { steps: 4, ..HarnessConfig::default() };
    let plans = [
        EpochPlan { world: 3, start_step: 0, departed_slots: Vec::new(), joined: 0 },
        EpochPlan { world: 2, start_step: 1, departed_slots: vec![1], joined: 0 },
    ];
    let (a, bytes_a) = elastic_oracle_trajectory(&cfg, &plans).unwrap();
    let (b, bytes_b) = elastic_oracle_trajectory(&cfg, &plans).unwrap();
    assert_eq!(bytes_a, bytes_b);
    for (ta, tb) in a.iter().zip(b.iter()) {
        assert_eq!(ta.data(), tb.data());
    }
    let (full, _) = oracle_trajectory(3, &cfg).unwrap();
    let drifted = a.iter().zip(full.iter()).any(|(ta, tb)| ta.data() != tb.data());
    assert!(drifted, "dropping a worker must change the trajectory");
}

/// Multi-process churn smoke (the CI `churn-smoke` job runs the same
/// scenario from the shell): a 4-process `launch --elastic` with a
/// deterministic boundary crash completes at `W=3` and prints the epoch
/// transition.
#[test]
fn multiprocess_elastic_launch_survives_an_injected_crash() {
    let exe = env!("CARGO_BIN_EXE_powersgd");
    let output = std::process::Command::new(exe)
        .args([
            "launch",
            "--workers",
            "4",
            "--compressor",
            "powersgd",
            "--rank",
            "2",
            "--steps",
            "4",
            "--seed",
            "7",
            "--elastic",
            "--fail-rank",
            "2",
            "--fail-at-step",
            "1",
        ])
        .output()
        .expect("spawning powersgd launch --elastic");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "elastic launch failed ({}):\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(
        stdout.contains("bitwise-identical to the composed elastic oracle"),
        "missing elastic verification line in:\n{stdout}"
    );
    assert!(
        stderr.contains("epoch 1: world 3"),
        "missing epoch transition in:\n{stderr}"
    );
}
