//! Integration: the kernel execution layer (DESIGN.md §11).
//!
//! Property suite pinning the pool's hard invariant: every parallel
//! kernel — the three GEMMs and Gram–Schmidt — is **bitwise identical**
//! to its serial (1-thread) run at threads ∈ {1, 2, 4, 8}, across the
//! paper's layer shapes and the degenerate edges (n=1, m=1, r=1,
//! rank-deficient Gram–Schmidt columns, zero matrices). On top of the
//! per-kernel properties, a full rank-2 PowerSGD
//! `compress_aggregate` step (warm start included) must produce
//! identical bits at every thread count — the acceptance invariant that
//! makes `--threads` a pure wall-clock knob.
//!
//! Plus the zero-alloc steady state of the centralized oracle: after
//! step 1 of a shape-stable workload, `PowerSgd`'s factor arena must
//! stop allocating (the per-worker `ScratchArena` counterpart lives in
//! `tests/integration_decentralized.rs`).
//!
//! The thread count is process-global, so tests that flip it serialize
//! on a local lock. (The kernels themselves are thread-count invariant
//! — that is the property under test — so a racing reader could never
//! observe different *bits*, only different wall-clock.)

use powersgd::collectives::CommLog;
use powersgd::compress::{Compressor, PowerSgd};
use powersgd::linalg::{gram_schmidt_in_place, orthonormal_error};
use powersgd::runtime::pool::{set_threads, threads, REDUCE_CHUNK};
use powersgd::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Tensor};
use powersgd::util::Rng;
use std::sync::{Mutex, MutexGuard};

static THREADS_LOCK: Mutex<()> = Mutex::new(());

/// Serializes the thread-sweeping tests and remembers the ambient
/// thread count so teardown restores it — hardcoding 1 would silently
/// downgrade the rest of the binary during the CI `POWERSGD_THREADS=4`
/// pass.
struct ThreadSweep {
    _guard: MutexGuard<'static, ()>,
    ambient: usize,
}

impl Drop for ThreadSweep {
    fn drop(&mut self) {
        set_threads(self.ambient);
    }
}

fn lock() -> ThreadSweep {
    let guard = THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    ThreadSweep { _guard: guard, ambient: threads() }
}

const SWEEP: [usize; 4] = [1, 2, 4, 8];

fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

/// The paper's dominant layer shapes plus degenerate edges.
const GEMM_SHAPES: [(usize, usize); 7] =
    [(512, 4608), (2600, 650), (128, 1152), (1, 1), (1, 7), (7, 1), (40, 300)];

#[test]
fn gemms_bitwise_identical_across_thread_counts() {
    let _g = lock();
    let mut rng = Rng::new(301);
    for &(n, m) in &GEMM_SHAPES {
        // Full rank sweep on the small shapes; the two big paper layers
        // only need the rank extremes (debug-mode CI time).
        let ranks: &[usize] = if n * m > 500_000 { &[1, 4] } else { &[1, 2, 4, 8] };
        for &r in ranks {
            let a = rand_tensor(&[n, m], &mut rng);
            let b = rand_tensor(&[m, r], &mut rng);
            let p = rand_tensor(&[n, r], &mut rng);
            let q = rand_tensor(&[m, r], &mut rng);

            set_threads(1);
            let mut ab = Tensor::zeros(&[n, r]);
            matmul_into(&a, &b, &mut ab);
            let mut atp = Tensor::zeros(&[m, r]);
            matmul_tn_into(&a, &p, &mut atp);
            let mut pqt = Tensor::zeros(&[n, m]);
            matmul_nt_into(&p, &q, &mut pqt);

            for &t in &SWEEP[1..] {
                set_threads(t);
                let mut got = Tensor::zeros(&[n, r]);
                matmul_into(&a, &b, &mut got);
                assert_eq!(got.data(), ab.data(), "matmul n={n} m={m} r={r} t={t}");
                let mut got = Tensor::zeros(&[m, r]);
                matmul_tn_into(&a, &p, &mut got);
                assert_eq!(got.data(), atp.data(), "matmul_tn n={n} m={m} r={r} t={t}");
                let mut got = Tensor::zeros(&[n, m]);
                matmul_nt_into(&p, &q, &mut got);
                assert_eq!(got.data(), pqt.data(), "matmul_nt n={n} m={m} r={r} t={t}");
            }
        }
    }
}

#[test]
fn gram_schmidt_bitwise_identical_across_thread_counts() {
    let _g = lock();
    let mut rng = Rng::new(302);
    // Includes n spanning the REDUCE_CHUNK boundary (the fixed-chunk
    // pairwise reduction must not care) and the paper's largest GS
    // input (the 28869-row LSTM embedding factor).
    let shapes: [(usize, usize); 8] = [
        (1, 1),
        (4, 1),
        (513, 8),
        (REDUCE_CHUNK, 2),
        (REDUCE_CHUNK + 1, 3),
        (2600, 4),
        (8192, 4),
        (28869, 2),
    ];
    for &(n, r) in &shapes {
        let p0 = rand_tensor(&[n, r], &mut rng);
        set_threads(1);
        let mut want = p0.clone();
        gram_schmidt_in_place(&mut want);
        for &t in &SWEEP[1..] {
            set_threads(t);
            let mut got = p0.clone();
            gram_schmidt_in_place(&mut got);
            assert_eq!(got.data(), want.data(), "gram_schmidt n={n} r={r} t={t}");
        }
        // And it still does its job at the highest thread count.
        set_threads(8);
        let mut p = p0.clone();
        gram_schmidt_in_place(&mut p);
        assert!(orthonormal_error(&p) < 1e-3, "n={n} r={r}");
    }
}

#[test]
fn rank_deficient_gram_schmidt_is_deterministic_and_stays_zero() {
    let _g = lock();
    // Duplicate columns across a reduction-chunk boundary: the
    // dependent column must collapse to exact zeros (not an arbitrary
    // unit direction) at every thread count, with identical bits.
    let n = REDUCE_CHUNK + 37;
    let mut rng = Rng::new(303);
    let mut p0 = Tensor::zeros(&[n, 3]);
    rng.fill_normal(p0.data_mut(), 1.0);
    for i in 0..n {
        let v = p0.at(i, 0);
        p0.set(i, 2, v); // column 2 duplicates column 0
    }
    set_threads(1);
    let mut want = p0.clone();
    gram_schmidt_in_place(&mut want);
    let dep_norm: f64 = (0..n).map(|i| (want.at(i, 2) as f64).powi(2)).sum::<f64>().sqrt();
    assert!(dep_norm < 0.1, "dependent column must stay small: {dep_norm}");
    for &t in &SWEEP[1..] {
        set_threads(t);
        let mut got = p0.clone();
        gram_schmidt_in_place(&mut got);
        assert_eq!(got.data(), want.data(), "rank-deficient GS t={t}");
    }
    // Zero matrix edge: finite and zero everywhere, at every count.
    for &t in &SWEEP {
        set_threads(t);
        let mut z = Tensor::zeros(&[REDUCE_CHUNK + 5, 2]);
        gram_schmidt_in_place(&mut z);
        assert!(z.data().iter().all(|v| *v == 0.0), "zero matrix t={t}");
    }
}

/// The acceptance invariant: a full warm-started rank-2 PowerSGD
/// compress step (GEMM sweeps, all-reduces, Gram–Schmidt,
/// reconstruction) produces bitwise-identical aggregates at
/// threads ∈ {1, 2, 4, 8}, across multiple steps so the warm-start `Q`
/// state is covered too. One matrix is taller than REDUCE_CHUNK so the
/// chunked Gram–Schmidt reductions are genuinely multi-chunk.
#[test]
fn powersgd_full_step_bitwise_identical_across_thread_counts() {
    let _g = lock();
    let shapes: [&[usize]; 4] = [&[4500, 64], &[12, 8], &[5], &[64, 80]];
    let steps = 3;
    let workers = 2;
    let updates_for = |step: usize| -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(900 + step as u64);
        (0..workers)
            .map(|_| shapes.iter().map(|s| rand_tensor(s, &mut rng)).collect())
            .collect()
    };

    set_threads(1);
    let mut reference = PowerSgd::new(2, 17);
    let mut want: Vec<Vec<Tensor>> = Vec::new();
    for step in 0..steps {
        let mut log = CommLog::default();
        want.push(reference.compress_aggregate(&updates_for(step), &mut log).mean);
    }

    for &t in &SWEEP[1..] {
        set_threads(t);
        let mut comp = PowerSgd::new(2, 17);
        for step in 0..steps {
            let mut log = CommLog::default();
            let got = comp.compress_aggregate(&updates_for(step), &mut log);
            for (p, (a, b)) in got.mean.iter().zip(want[step].iter()).enumerate() {
                assert_eq!(a.shape(), b.shape(), "step {step} mean[{p}] shape t={t}");
                assert_eq!(a.data(), b.data(), "step {step} mean[{p}] bits t={t}");
            }
        }
    }
}

/// Zero-alloc steady state of the *centralized* oracle: the factor
/// arena claims every buffer on step 1 of a shape-stable workload and
/// never allocates again (the satellite to the per-worker ScratchArena
/// counter test).
#[test]
fn centralized_powersgd_arena_stops_allocating_after_first_step() {
    let shapes: [&[usize]; 4] = [&[12, 8], &[5], &[6, 10], &[3]];
    let updates_for = |seed: u64| -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(seed);
        (0..4).map(|_| shapes.iter().map(|s| rand_tensor(s, &mut rng)).collect()).collect()
    };
    let mut comp = PowerSgd::new(2, 31);
    assert_eq!(
        Compressor::scratch_allocations(&comp),
        Some(0),
        "fresh oracle has an empty arena"
    );
    let mut log = CommLog::default();
    comp.compress_aggregate(&updates_for(1000), &mut log);
    let after_first = Compressor::scratch_allocations(&comp).expect("arena-backed oracle");
    assert!(after_first > 0, "step 1 must claim the factor buffers");
    for step in 0..5u64 {
        comp.compress_aggregate(&updates_for(1001 + step), &mut log);
        assert_eq!(
            Compressor::scratch_allocations(&comp),
            Some(after_first),
            "step {step} allocated new factor tensors"
        );
    }
}
