//! Integration: pipelined collective scheduling (`--pipeline`).
//!
//! Three properties, one per ISSUE acceptance clause:
//!
//! - **Overlap is a reordering, not a change** — `--pipeline overlap`
//!   posts the vector all-reduce early and drains it while the factor
//!   collectives run, but every floating-point operation happens on the
//!   same values in the same order, so the aggregate must stay
//!   **bitwise identical** to the lockstep schedule on both backends
//!   (in-process mpsc ring and real TCP sockets), at W ∈ {2, 4} and
//!   kernel-thread counts ∈ {1, 4}.
//! - **Delayed aggregation trains** — `--pipeline delayed` applies step
//!   t−1's aggregate at step t (the DDP PowerSGD-hook trick). The launch
//!   harness verifies every worker bitwise against a one-step-delayed
//!   oracle, and the delayed oracle itself must be deterministic, move
//!   the parameters, and differ from the synchronous trajectory.
//! - **Failures surface, not hang** — a worker dying with posted
//!   operations still in flight delivers the frames it already sent,
//!   then panics its peers with the contract's named-rank messages.

use powersgd::collectives::CommLog;
use powersgd::compress::{decentralized_by_name, Compressor, PowerSgd};
use powersgd::tensor::Tensor;
use powersgd::transport::tcp::{
    coordinate, initial_params, oracle_trajectory, run_worker, HarnessConfig, LaunchOutcome,
    Rendezvous,
};
use powersgd::transport::{Completion, InProcRing, PipelineMode, Transport};
use powersgd::util::Rng;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

/// Mixed matrix/vector shapes, vectors interleaved like a real model.
const SHAPES: &[&[usize]] = &[&[12, 8], &[5], &[6, 10], &[3]];

fn rand_updates(w: usize, seed: u64) -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(seed);
    (0..w)
        .map(|_| {
            SHAPES
                .iter()
                .map(|s| {
                    let mut t = Tensor::zeros(s);
                    rng.fill_normal(t.data_mut(), 1.0);
                    t
                })
                .collect()
        })
        .collect()
}

/// Rendezvous `world` worker threads over real localhost sockets and
/// run the full harness; panics (via the Results) on any divergence
/// from the pipeline-matched oracle.
fn run_socket_ring(world: usize, cfg: &HarnessConfig) -> LaunchOutcome {
    let rendezvous = Rendezvous::bind("127.0.0.1:0").expect("bind rendezvous");
    let addr = rendezvous.addr().expect("rendezvous addr");
    let workers: Vec<_> = (0..world)
        .map(|_| {
            let addr = addr.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || run_worker(&addr, &cfg, TIMEOUT))
        })
        .collect();
    let outcome = coordinate(&rendezvous, world, cfg, TIMEOUT);
    for (idx, handle) in workers.into_iter().enumerate() {
        handle
            .join()
            .expect("worker thread panicked")
            .unwrap_or_else(|e| panic!("worker #{idx}: {e:#}"));
    }
    outcome.unwrap_or_else(|e| panic!("coordinate: {e:#}"))
}

/// Overlap vs the lockstep oracle on the in-process mpsc backend:
/// bitwise-equal aggregates, locals, byte accounting and op logs at
/// W ∈ {2, 4} × kernel threads ∈ {1, 4}, across warm-started steps.
#[test]
fn overlap_is_bitwise_identical_to_lockstep_on_the_mpsc_ring() {
    let ambient = powersgd::runtime::pool::threads();
    for &threads in &[1usize, 4] {
        powersgd::runtime::pool::set_threads(threads);
        for &w in &[2usize, 4] {
            let mut overlapped = decentralized_by_name("powersgd", 2, 13)
                .unwrap()
                .with_pipeline(PipelineMode::Overlap);
            let mut oracle = PowerSgd::new(2, 13);
            for step in 0..3u64 {
                let updates = rand_updates(w, 40 + 10 * w as u64 + step);
                let mut plog = CommLog::default();
                let mut olog = CommLog::default();
                let p = overlapped.compress_aggregate(&updates, &mut plog);
                let o = oracle.compress_aggregate(&updates, &mut olog);
                let ctx = format!("w={w} threads={threads} step={step}");
                for (i, (a, b)) in p.mean.iter().zip(o.mean.iter()).enumerate() {
                    assert_eq!(a.data(), b.data(), "mean[{i}] bits ({ctx})");
                }
                assert_eq!(plog.bytes_sent(), olog.bytes_sent(), "bytes ({ctx})");
                assert_eq!(plog.ops.len(), olog.ops.len(), "op count ({ctx})");
            }
        }
    }
    powersgd::runtime::pool::set_threads(ambient);
}

/// Overlap vs the lockstep oracle over real TCP sockets: `coordinate`
/// verifies every worker's final EF-SGD parameters bitwise against the
/// oracle trajectory, which runs the *lockstep* schedule (overlap only
/// reorders worker-side traffic), so success is the acceptance check.
#[test]
fn overlap_is_bitwise_identical_to_lockstep_over_tcp_sockets() {
    for world in [2usize, 4] {
        let cfg = HarnessConfig {
            seed: 31,
            steps: 3,
            pipeline: PipelineMode::Overlap,
            ..HarnessConfig::default()
        };
        let outcome = run_socket_ring(world, &cfg);
        assert_eq!(outcome.reports.len(), world);
        assert!(
            outcome.reports.iter().all(|r| r.bitwise),
            "w={world}: overlap diverged from the lockstep oracle"
        );
    }
}

/// The overlap schedule composes with multi-threaded kernels over
/// sockets: W=2 workers × 4 kernel threads each, still bitwise.
#[test]
fn overlap_socket_ring_with_kernel_threads_stays_bitwise() {
    let ambient = powersgd::runtime::pool::threads();
    powersgd::runtime::pool::set_threads(4);
    let cfg = HarnessConfig {
        seed: 37,
        steps: 3,
        pipeline: PipelineMode::Overlap,
        ..HarnessConfig::default()
    };
    let outcome = run_socket_ring(2, &cfg);
    assert!(outcome.reports.iter().all(|r| r.bitwise));
    powersgd::runtime::pool::set_threads(ambient);
}

/// True multi-process acceptance: the binary's `launch` subcommand
/// forwards `--pipeline overlap` to every spawned `powersgd worker`
/// process and still verifies bitwise against the lockstep oracle.
#[test]
fn multiprocess_launch_accepts_pipeline_overlap() {
    let exe = env!("CARGO_BIN_EXE_powersgd");
    let output = std::process::Command::new(exe)
        .args([
            "launch", "--workers", "2", "--transport", "tcp", "--compressor", "powersgd",
            "--rank", "2", "--steps", "3", "--seed", "7", "--pipeline", "overlap",
        ])
        .output()
        .expect("spawning powersgd launch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "launch --pipeline overlap failed ({}):\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(
        stdout.contains("bitwise-identical to the lockstep oracle"),
        "launch --pipeline overlap: missing verification line in:\n{stdout}"
    );
}

/// Delayed aggregation in the launch harness: workers run one-step-
/// delayed EF-SGD over real sockets and `coordinate` verifies them
/// bitwise against the one-step-delayed oracle (the harness threads the
/// mode into both halves).
#[test]
fn delayed_mode_trains_bitwise_in_the_socket_harness() {
    let cfg = HarnessConfig {
        seed: 41,
        steps: 4,
        pipeline: PipelineMode::Delayed,
        ..HarnessConfig::default()
    };
    let outcome = run_socket_ring(2, &cfg);
    assert!(
        outcome.reports.iter().all(|r| r.bitwise),
        "delayed workers diverged from the delayed oracle"
    );
}

/// The delayed oracle itself: deterministic, moves the parameters
/// (it converges on the quadratic — pinned in src/optim), and is a
/// genuinely different trajectory from the synchronous schedule (the
/// first applied aggregate lags one step).
#[test]
fn delayed_oracle_moves_and_differs_from_synchronous() {
    let sync_cfg = HarnessConfig { seed: 43, steps: 4, ..HarnessConfig::default() };
    let delayed_cfg =
        HarnessConfig { pipeline: PipelineMode::Delayed, ..sync_cfg.clone() };

    let (sync_params, sync_bytes) = oracle_trajectory(2, &sync_cfg).unwrap();
    let (delayed_a, bytes_a) = oracle_trajectory(2, &delayed_cfg).unwrap();
    let (delayed_b, bytes_b) = oracle_trajectory(2, &delayed_cfg).unwrap();

    // Deterministic, and the delay changes when aggregates apply — not
    // how much traffic the compressor logs.
    assert_eq!(bytes_a, bytes_b);
    assert_eq!(bytes_a, sync_bytes);
    for (a, b) in delayed_a.iter().zip(delayed_b.iter()) {
        assert_eq!(a.data(), b.data(), "delayed oracle must be deterministic");
    }

    let x0 = initial_params(delayed_cfg.seed);
    assert!(
        delayed_a.iter().zip(x0.iter()).any(|(t, t0)| t.data() != t0.data()),
        "delayed EF-SGD must move the parameters"
    );
    assert!(
        delayed_a.iter().zip(sync_params.iter()).any(|(d, s)| d.data() != s.data()),
        "delayed trajectory should lag the synchronous one, not equal it"
    );
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(msg) = payload.downcast_ref::<String>() {
        msg.clone()
    } else if let Some(msg) = payload.downcast_ref::<&'static str>() {
        (*msg).to_string()
    } else {
        String::new()
    }
}

/// Kill-a-worker under in-flight posted operations: frames already on a
/// link still fulfill their tickets after the sender dies; the first
/// operation that *needs* the dead rank panics with the contract's
/// named-role message instead of hanging.
#[test]
fn worker_death_surfaces_on_in_flight_posted_operations() {
    let mut nodes = InProcRing::endpoints::<Vec<f32>>(3);
    let node2 = nodes.pop().unwrap();
    let node1 = nodes.pop().unwrap();
    let node0 = nodes.pop().unwrap();

    // Rank 2 posts two receives up front (a pipelined schedule's shape),
    // rank 1 delivers one frame and dies mid-collective.
    let first = node2.post_recv();
    let second = node2.post_recv();
    node1.post_send(vec![1.0, 2.0]);
    drop(node1);

    // The in-flight frame is not lost: its ticket still resolves.
    assert_eq!(node2.wait(first), Completion::Received(vec![1.0, 2.0]));
    // The ticket with no sender left fails loudly.
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| node2.wait(second)))
        .expect_err("waiting on a dead predecessor must not hang");
    assert!(
        panic_text(err.as_ref()).contains("ring predecessor hung up"),
        "unhelpful wait panic: {}",
        panic_text(err.as_ref())
    );
    // Posting toward the dead rank fails at post time, per the
    // posted-send contract (failure surfaces on a later operation —
    // here the very next post on that endpoint).
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        node0.post_send(vec![3.0]);
    }))
    .expect_err("posting to a dead successor must not hang");
    assert!(
        panic_text(err.as_ref()).contains("ring successor hung up"),
        "unhelpful post panic: {}",
        panic_text(err.as_ref())
    );
}

/// The decentralized overlap path also surfaces a dead worker: one
/// fleet member panicking mid-round (simulated by a poisoned thread)
/// must not deadlock the others. Covered here by driving the fleet
/// adapter with a world size of 1 after a larger round — the adapter
/// rebuilds worker state and the survivors' scratch stays coherent.
#[test]
fn overlap_fleet_survives_world_size_changes() {
    let mut dec = decentralized_by_name("powersgd", 2, 17)
        .unwrap()
        .with_pipeline(PipelineMode::Overlap);
    let mut log = CommLog::default();
    let up4 = rand_updates(4, 1900);
    dec.compress_aggregate(&up4, &mut log);
    // Shrinking the world rebuilds per-worker state; the overlapped
    // round must still match a fresh lockstep oracle at the new W.
    let up2 = rand_updates(2, 1901);
    let d = dec.compress_aggregate(&up2, &mut log);
    let mut fresh = PowerSgd::new(2, 17);
    let o = fresh.compress_aggregate(&up2, &mut log);
    for (i, (a, b)) in d.mean.iter().zip(o.mean.iter()).enumerate() {
        assert_eq!(a.data(), b.data(), "mean[{i}] bits after W change");
    }
}
