//! Integration: the multi-process TCP ring transport against the
//! lockstep oracle.
//!
//! Two layers of coverage:
//!
//! - **True multi-process** — spawn the `powersgd` binary's `launch`
//!   subcommand, which forks W `powersgd worker` OS processes,
//!   rendezvouses them into a localhost ring, runs a PowerSGD EF-SGD
//!   trajectory over real sockets, and verifies it bitwise against the
//!   in-process oracle. The launch exits non-zero on any divergence,
//!   dead worker, or byte-accounting mismatch, so a passing exit status
//!   *is* the equivalence assertion.
//! - **In-process, real sockets** — the same harness driven by threads
//!   in this test process (one `run_worker` per thread against a
//!   `coordinate` call), which lets us assert on the returned
//!   [`LaunchOutcome`] directly: per-rank measured wire bytes and the
//!   exact `Scheme::message_bytes` cross-check.

use powersgd::simulate::Scheme;
use powersgd::transport::tcp::{
    coordinate, harness_registry, join, run_worker, worker_trajectory, HarnessConfig,
    LaunchOutcome, MeteredTransport, Rendezvous, TcpRing,
};
use powersgd::transport::PipelineMode;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

/// Rendezvous `world` worker threads over real localhost sockets and
/// run the full harness; panics (via the Results) on any divergence.
fn run_socket_ring(world: usize, cfg: &HarnessConfig) -> LaunchOutcome {
    let rendezvous = Rendezvous::bind("127.0.0.1:0").expect("bind rendezvous");
    let addr = rendezvous.addr().expect("rendezvous addr");
    let workers: Vec<_> = (0..world)
        .map(|_| {
            let addr = addr.clone();
            let cfg = cfg.clone();
            std::thread::spawn(move || run_worker(&addr, &cfg, TIMEOUT))
        })
        .collect();
    let outcome = coordinate(&rendezvous, world, cfg, TIMEOUT);
    for (idx, handle) in workers.into_iter().enumerate() {
        handle
            .join()
            .expect("worker thread panicked")
            .unwrap_or_else(|e| panic!("worker #{idx}: {e:#}"));
    }
    outcome.unwrap_or_else(|e| panic!("coordinate: {e:#}"))
}

/// Acceptance: a full multi-process PowerSGD EF-SGD run over `TcpRing`
/// on localhost is bitwise-identical to the lockstep oracle at
/// W ∈ {2, 4} — real `powersgd worker` OS processes, spawned by the
/// binary's `launch` subcommand.
#[test]
fn multiprocess_powersgd_launch_is_bitwise_identical_at_w2_and_w4() {
    let exe = env!("CARGO_BIN_EXE_powersgd");
    for workers in [2usize, 4] {
        let output = std::process::Command::new(exe)
            .args([
                "launch",
                "--workers",
                &workers.to_string(),
                "--transport",
                "tcp",
                "--compressor",
                "powersgd",
                "--rank",
                "2",
                "--steps",
                "3",
                "--seed",
                "7",
            ])
            .output()
            .expect("spawning powersgd launch");
        let stdout = String::from_utf8_lossy(&output.stdout);
        let stderr = String::from_utf8_lossy(&output.stderr);
        assert!(
            output.status.success(),
            "launch --workers {workers} failed ({}):\nstdout:\n{stdout}\nstderr:\n{stderr}",
            output.status
        );
        assert!(
            stdout.contains("bitwise-identical to the lockstep oracle"),
            "launch --workers {workers}: missing verification line in:\n{stdout}"
        );
    }
}

/// Multi-threaded-kernels variant: the same multi-process launch with
/// the kernel pool fanned out to 4 threads in the coordinator *and*
/// every worker process (`--threads` is forwarded; W worker processes
/// × 4 kernel threads each). Kernels are bitwise identical at every
/// thread count, so the launch's built-in oracle verification must
/// still pass — transport-level bitwise equivalence is preserved.
#[test]
fn multiprocess_launch_with_kernel_threads_is_bitwise_identical() {
    let exe = env!("CARGO_BIN_EXE_powersgd");
    let output = std::process::Command::new(exe)
        .args([
            "launch",
            "--workers",
            "2",
            "--transport",
            "tcp",
            "--compressor",
            "powersgd",
            "--rank",
            "2",
            "--steps",
            "3",
            "--seed",
            "7",
            "--threads",
            "4",
        ])
        .output()
        .expect("spawning powersgd launch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "launch --threads 4 failed ({}):\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(
        stdout.contains("bitwise-identical to the lockstep oracle"),
        "launch --threads 4: missing verification line in:\n{stdout}"
    );
}

/// In-process socket-ring variant of the same composition: worker
/// threads over real localhost sockets, each dispatching kernels onto
/// the shared 4-thread pool; `coordinate` still verifies every worker
/// bitwise against the oracle.
#[test]
fn socket_ring_equivalence_with_kernel_threads() {
    let ambient = powersgd::runtime::pool::threads();
    powersgd::runtime::pool::set_threads(4);
    let cfg = HarnessConfig { seed: 29, steps: 3, ..HarnessConfig::default() };
    let outcome = run_socket_ring(2, &cfg);
    assert!(outcome.reports.iter().all(|r| r.bitwise), "non-bitwise report at 4 kernel threads");
    powersgd::runtime::pool::set_threads(ambient);
}

/// The same equivalence for every scheme with a per-worker
/// implementation, over real sockets (threads in this process so the
/// sweep stays fast), at W ∈ {2, 4}. `coordinate` bails unless every
/// worker's final parameters are bit-identical to the oracle and all
/// three byte-accounting layers agree, so success is the assertion.
#[test]
fn socket_ring_equivalence_across_schemes() {
    for name in ["powersgd", "unbiased-rank", "sign-norm", "top-k", "none"] {
        for world in [2usize, 4] {
            let cfg = HarnessConfig {
                compressor: name.into(),
                rank: 2,
                seed: 11,
                steps: 3,
                ..HarnessConfig::default()
            };
            let outcome = run_socket_ring(world, &cfg);
            assert_eq!(outcome.reports.len(), world, "{name} w={world}");
            assert!(
                outcome.reports.iter().all(|r| r.bitwise),
                "{name} w={world}: non-bitwise report"
            );
        }
    }
}

/// Measured-bytes acceptance: the per-step logical bytes of the TCP run
/// equal `Scheme::message_bytes` **exactly** for the rank-r and sign
/// schemes, and the measured wire bytes are consistent across workers
/// (each worker's sends are its predecessor's receives; the worker-side
/// cross-check against the `ring_wire_bytes` expansion already ran
/// inside `run_worker`).
#[test]
fn metered_wire_bytes_match_scheme_message_bytes_model() {
    let reg = harness_registry();
    let cases: [(&str, Scheme); 2] =
        [("powersgd", Scheme::PowerSgd { rank: 2 }), ("sign-norm", Scheme::SignNorm)];
    for (name, scheme) in cases {
        for world in [2usize, 4] {
            let steps = 3usize;
            let cfg = HarnessConfig {
                compressor: name.into(),
                rank: 2,
                seed: 23,
                steps,
                ..HarnessConfig::default()
            };
            let outcome = run_socket_ring(world, &cfg);
            let model = scheme.message_bytes(&reg);
            assert_eq!(
                outcome.model_bytes_per_step, model,
                "{name} w={world}: worker model vs simulator scheme model"
            );
            for report in &outcome.reports {
                assert_eq!(
                    report.logical_bytes,
                    model * steps as u64,
                    "{name} w={world} rank {}: logical bytes must equal \
                     Scheme::message_bytes × steps exactly",
                    report.rank
                );
                assert!(
                    report.wire_bytes > 0,
                    "{name} w={world} rank {}: nothing crossed the wire?",
                    report.rank
                );
            }
            // The ring moves strictly more than the logical unit for
            // W > 1 all-reduce (2(W−1)/W ≥ 1 only at W = 2, where the
            // expansion equals the logical volume for even splits).
            let total_wire: u64 = outcome.reports.iter().map(|r| r.wire_bytes).sum();
            let total_logical: u64 = outcome.reports.iter().map(|r| r.logical_bytes).sum();
            if scheme.all_reduce() {
                // Σ_ranks wire = 2(W−1)/W × Σ_ranks logical per op.
                assert_eq!(
                    total_wire * world as u64,
                    total_logical * 2 * (world as u64 - 1),
                    "{name} w={world}: aggregate ring bandwidth identity"
                );
            } else {
                // Gather schemes mix one packed all-reduce (vectors)
                // with the gather; just require the gather expansion to
                // dominate the logical volume at W > 2.
                assert!(total_wire >= total_logical, "{name} w={world}");
            }
        }
    }
}

/// Graceful failure: a worker that dies mid-run surfaces as a
/// contextual error on the coordinator (naming the dead worker), not a
/// hang. Uses a 2-worker launch where one worker is killed right after
/// rendezvous by giving it an impossible compressor — it exits before
/// its first collective, and the survivor's recv times out or sees the
/// closed connection.
#[test]
fn coordinator_reports_death_instead_of_hanging() {
    let rendezvous = Rendezvous::bind("127.0.0.1:0").expect("bind");
    let addr = rendezvous.addr().expect("addr");
    let cfg = HarnessConfig { steps: 2, ..HarnessConfig::default() };

    // Worker A runs the real harness with a short timeout; worker B
    // joins the ring, then dies before compressing anything.
    let short = Duration::from_millis(500);
    let a = {
        let addr = addr.clone();
        let cfg = cfg.clone();
        std::thread::spawn(move || run_worker(&addr, &cfg, short))
    };
    let b = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let joined = powersgd::transport::tcp::join(&addr, short)?;
            drop(joined); // dies: all sockets close
            Ok::<(), anyhow::Error>(())
        })
    };

    let outcome = coordinate(&rendezvous, 2, &cfg, Duration::from_secs(5));
    b.join().unwrap().unwrap();
    let worker_err = a.join().unwrap().expect_err("survivor must error, not hang");
    let msg = format!("{worker_err:#}");
    assert!(
        msg.contains("ring collective failed") || msg.contains("rank"),
        "unhelpful worker error: {msg}"
    );
    // The survivor names its dead peer.
    assert!(
        msg.contains("closed the connection") || msg.contains("timed out") || msg.contains("cannot send"),
        "error does not explain the dead peer: {msg}"
    );
    let coord_err = outcome.expect_err("coordinator must notice the dead worker");
    assert!(
        format!("{coord_err:#}").contains("died before reporting"),
        "unhelpful coordinator error: {coord_err:#}"
    );
}

/// Killing *each* ring position (first, middle, last rank of a
/// 3-worker ring) mid-run surfaces an error on every survivor that
/// names the survivor's **correct** ring neighbor — never a
/// misattributed rank — including with completion-queue tickets in
/// flight (`--pipeline overlap` posts collectives early, so the peer
/// dies with posted-but-unresolved tickets outstanding).
///
/// The doomed worker runs one full step (so every survivor's step-0
/// collective completes) and then drops its sockets; the survivors'
/// step-1 collectives hit the EOF cascade. A survivor's error may blame
/// either the dead rank or the neighbor that tore down in response —
/// both are *its* real neighbors; what must never happen is blaming a
/// rank that is not adjacent to it.
#[test]
fn killed_worker_at_each_ring_position_names_the_right_neighbor() {
    let world = 3usize;
    for pipeline in [PipelineMode::Off, PipelineMode::Overlap] {
        for dead in 0..world {
            let rendezvous = Rendezvous::bind("127.0.0.1:0").expect("bind");
            let addr = rendezvous.addr().expect("addr");
            let survivor_cfg =
                HarnessConfig { steps: 2, pipeline, ..HarnessConfig::default() };
            let doomed_cfg = HarnessConfig { steps: 1, ..survivor_cfg.clone() };
            let short = Duration::from_millis(800);

            let threads: Vec<_> = (0..world)
                .map(|_| {
                    let addr = addr.clone();
                    let survivor_cfg = survivor_cfg.clone();
                    let doomed_cfg = doomed_cfg.clone();
                    std::thread::spawn(move || -> (usize, anyhow::Result<()>) {
                        let joined = join(&addr, TIMEOUT).expect("join");
                        let rank = joined.rank;
                        let (ring, _control) =
                            TcpRing::from_joined(joined, short).expect("ring");
                        let cfg = if rank == dead { &doomed_cfg } else { &survivor_cfg };
                        let result =
                            worker_trajectory(MeteredTransport::new(ring), cfg).map(|_| ());
                        (rank, result)
                    })
                })
                .collect();
            // Keep the control streams alive until the workers finish;
            // no coordinate() here — the trajectories never report.
            let controls = rendezvous.run(world, TIMEOUT).expect("rendezvous");

            for handle in threads {
                let (rank, result) = handle.join().expect("worker thread panicked");
                if rank == dead {
                    result.unwrap_or_else(|e| {
                        panic!("doomed rank {rank} must finish its single step: {e:#}")
                    });
                    continue;
                }
                let err = result
                    .expect_err(&format!("survivor {rank} must error once rank {dead} is gone"));
                let msg = format!("{err:#}");
                let pred = (rank + world - 1) % world;
                let succ = (rank + 1) % world;
                assert!(
                    msg.contains("ring collective failed at step 1"),
                    "survivor {rank} (dead {dead}, {pipeline:?}): failed outside step 1: {msg}"
                );
                assert!(
                    msg.contains(&format!("predecessor rank {pred}"))
                        || msg.contains(&format!("successor rank {succ}")),
                    "survivor {rank} (dead {dead}, {pipeline:?}) does not name a real \
                     neighbor: {msg}"
                );
                assert!(
                    !msg.contains(&format!("predecessor rank {succ}"))
                        && !msg.contains(&format!("successor rank {pred}")),
                    "survivor {rank} (dead {dead}, {pipeline:?}) misattributes the ring \
                     topology: {msg}"
                );
            }
            drop(controls);
        }
    }
}
