//! Integration: the decentralized per-worker compression path against
//! the centralized lockstep oracle.
//!
//! Engine-equivalence suite: for W ∈ {2, 4, 8}, threaded per-worker
//! PowerSGD / unbiased rank-r / sign (and top-K / no-compression) must
//! be **bitwise identical** to `Compressor::compress_aggregate` — same
//! aggregate, same per-worker locals, same byte accounting — across
//! multiple steps (warm-start state included). Plus the zero-alloc
//! regression: the per-worker `ScratchArena` must stop allocating
//! tensors after step 1 on a shape-stable workload.
//!
//! The decentralized path drives the `InProcRing` directly (engine
//! selection is per-`CommLog`, DESIGN.md §14), so the oracle side here
//! simply runs on `CommLog::default()`'s lockstep engine.

use powersgd::collectives::CommLog;
use powersgd::compress::{
    decentralized_by_name, Aggregated, Compressor, DecentralizedCompressor, NoCompression,
    PowerSgd, SchemeMeta, SignNorm, TopK, UnbiasedRank,
};
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule};
use powersgd::tensor::Tensor;
use powersgd::util::Rng;

/// Mixed matrix/vector shapes, vectors interleaved like a real model.
const SHAPES: &[&[usize]] = &[&[12, 8], &[5], &[6, 10], &[3]];

fn rand_updates(w: usize, shapes: &[&[usize]], seed: u64) -> Vec<Vec<Tensor>> {
    let mut rng = Rng::new(seed);
    (0..w)
        .map(|_| {
            shapes
                .iter()
                .map(|s| {
                    let mut t = Tensor::zeros(s);
                    rng.fill_normal(t.data_mut(), 1.0);
                    t
                })
                .collect()
        })
        .collect()
}

/// Exact equality of aggregate, per-worker locals and traffic.
fn assert_bitwise(dec: &Aggregated, oracle: &Aggregated, w: usize, ctx: &str) {
    assert_eq!(dec.mean.len(), oracle.mean.len(), "param count ({ctx})");
    for (p, (a, b)) in dec.mean.iter().zip(oracle.mean.iter()).enumerate() {
        assert_eq!(a.shape(), b.shape(), "mean[{p}] shape ({ctx})");
        assert_eq!(a.data(), b.data(), "mean[{p}] bits ({ctx})");
    }
    for wi in 0..w {
        for (p, (a, b)) in dec.local_for(wi).iter().zip(oracle.local_for(wi).iter()).enumerate() {
            assert_eq!(a.data(), b.data(), "local[{wi}][{p}] bits ({ctx})");
        }
    }
}

/// Run `steps` rounds on both paths with identical inputs and assert
/// bitwise-equal outputs and byte accounting at every step.
fn check_equivalence(
    mut dec: DecentralizedCompressor,
    mut oracle: Box<dyn Compressor>,
    w: usize,
    steps: usize,
    seed: u64,
) {
    for step in 0..steps {
        let updates = rand_updates(w, SHAPES, seed + step as u64);
        let mut dlog = CommLog::default();
        let mut olog = CommLog::default();
        let d = dec.compress_aggregate(&updates, &mut dlog);
        let o = oracle.compress_aggregate(&updates, &mut olog);
        let ctx = format!("{} w={w} step={step}", oracle.name());
        assert_bitwise(&d, &o, w, &ctx);
        assert_eq!(dlog.bytes_sent(), olog.bytes_sent(), "bytes ({ctx})");
        assert_eq!(dlog.ops.len(), olog.ops.len(), "op count ({ctx})");
    }
}

#[test]
fn powersgd_per_worker_matches_oracle_bitwise() {
    for &w in &[2usize, 4, 8] {
        check_equivalence(
            decentralized_by_name("powersgd", 2, 9).unwrap(),
            Box::new(PowerSgd::new(2, 9)),
            w,
            3, // multiple steps: warm-start Q state must track too
            100 + w as u64,
        );
    }
}

#[test]
fn powersgd_cold_start_matches_oracle_bitwise() {
    for &w in &[2usize, 4] {
        check_equivalence(
            decentralized_by_name("powersgd-cold", 1, 5).unwrap(),
            Box::new(PowerSgd::new(1, 5).without_warm_start()),
            w,
            2, // cold start re-samples Q every step on both paths
            200 + w as u64,
        );
    }
}

#[test]
fn unbiased_rank_per_worker_matches_oracle_bitwise() {
    for &w in &[2usize, 4, 8] {
        check_equivalence(
            decentralized_by_name("unbiased-rank", 2, 7).unwrap(),
            Box::new(UnbiasedRank::new(2, 7)),
            w,
            2, // shared-seed U must stay in lockstep across steps
            300 + w as u64,
        );
    }
}

#[test]
fn sign_norm_per_worker_matches_oracle_bitwise() {
    for &w in &[2usize, 4, 8] {
        check_equivalence(
            decentralized_by_name("sign-norm", 0, 0).unwrap(),
            Box::new(SignNorm::new()),
            w,
            2,
            400 + w as u64,
        );
    }
}

#[test]
fn top_k_per_worker_matches_oracle_bitwise() {
    for &w in &[2usize, 4, 8] {
        check_equivalence(
            decentralized_by_name("top-k", 2, 0).unwrap(),
            Box::new(TopK::new(2)),
            w,
            2,
            500 + w as u64,
        );
    }
}

#[test]
fn no_compression_per_worker_matches_oracle_bitwise() {
    for &w in &[2usize, 4, 8] {
        check_equivalence(
            decentralized_by_name("none", 0, 0).unwrap(),
            Box::new(NoCompression::new()),
            w,
            2,
            600 + w as u64,
        );
    }
}

#[test]
fn ef_sgd_trajectories_identical_on_both_paths() {
    // End-to-end: full EF-SGD (error feedback + momentum) produces the
    // exact same parameter deltas whether compression is centralized or
    // per-worker — the engine switch can never change training.
    let w = 4;
    let mut opt_dec = EfSgd::new(
        Box::new(decentralized_by_name("powersgd", 2, 3).unwrap()),
        LrSchedule::constant(0.05),
        0.9,
    );
    let mut opt_cen =
        EfSgd::new(Box::new(PowerSgd::new(2, 3)), LrSchedule::constant(0.05), 0.9);
    for step in 0..5 {
        let grads = rand_updates(w, SHAPES, 700 + step as u64);
        let mut dlog = CommLog::default();
        let mut olog = CommLog::default();
        let d = opt_dec.step(&grads, step, &mut dlog);
        let c = opt_cen.step(&grads, step, &mut olog);
        for (p, (a, b)) in d.iter().zip(c.iter()).enumerate() {
            assert_eq!(a.data(), b.data(), "delta[{p}] step {step}");
        }
    }
}

#[test]
fn scratch_arena_stops_allocating_after_first_step() {
    // Zero-alloc regression (counter hook): on a shape-stable workload
    // every reusable buffer is claimed on step 1; later steps must not
    // allocate any new tensor in any worker's arena.
    let w = 4;
    let mut dec = decentralized_by_name("powersgd", 2, 11).unwrap();
    let mut log = CommLog::default();

    let updates = rand_updates(w, SHAPES, 800);
    dec.compress_aggregate(&updates, &mut log);
    let after_first = dec.scratch_allocations();
    assert!(after_first > 0, "arena should own the P/Q buffers");

    for step in 0..5 {
        let updates = rand_updates(w, SHAPES, 801 + step as u64);
        dec.compress_aggregate(&updates, &mut log);
        assert_eq!(
            dec.scratch_allocations(),
            after_first,
            "step {step} allocated new scratch tensors"
        );
    }

    // The hook is also visible through the Compressor and optimizer
    // traits (the Trainer's log line uses the latter).
    assert_eq!(Compressor::scratch_allocations(&dec), Some(after_first));
    let opt = EfSgd::new(Box::new(dec), LrSchedule::constant(0.1), 0.0);
    assert_eq!(DistOptimizer::scratch_allocations(&opt), Some(after_first));
    // The centralized PowerSGD oracle is arena-backed too now (its own
    // zero-alloc counter test lives in tests/integration_kernels.rs);
    // before the first step its arena is empty.
    let centralized = EfSgd::new(Box::new(PowerSgd::new(2, 1)), LrSchedule::constant(0.1), 0.0);
    assert_eq!(DistOptimizer::scratch_allocations(&centralized), Some(0));
}

#[test]
fn scratch_arena_stays_flat_with_metrics_enabled() {
    // Run-health satellite (DESIGN.md §15): recording the quality
    // gauges/histograms must not allocate either — the metrics-enabled
    // path keeps the zero-alloc-after-step-1 property. The registry is
    // a fixed static table of atomics, so this holds by construction;
    // this test keeps it held.
    let w = 4;
    powersgd::obs::enable_metrics(true);
    let mut dec = decentralized_by_name("powersgd", 2, 13).unwrap();
    let mut log = CommLog::default();

    let updates = rand_updates(w, SHAPES, 850);
    dec.compress_aggregate(&updates, &mut log);
    let after_first = dec.scratch_allocations();
    assert!(after_first > 0, "arena should own the P/Q buffers");

    for step in 0..5 {
        let updates = rand_updates(w, SHAPES, 851 + step as u64);
        dec.compress_aggregate(&updates, &mut log);
        assert_eq!(
            dec.scratch_allocations(),
            after_first,
            "metrics-enabled step {step} allocated new scratch tensors"
        );
    }

    // The quality instrumentation really ran on this path: the
    // reconstruction loop published a finite relative error.
    let err = powersgd::obs::metrics::gauge_value(powersgd::obs::metrics::Gauge::ApproxError);
    assert!(err.is_finite() && err >= 0.0, "approx-error gauge not recorded: {err}");
    powersgd::obs::enable_metrics(false);
}

#[test]
fn per_worker_equivalence_holds_with_multithreaded_kernels() {
    // Engine-equivalence with the kernel pool fanned out: the
    // decentralized path must stay bitwise-identical to the oracle when
    // every worker thread dispatches its GEMMs/Gram–Schmidt onto 4
    // kernel threads (W workers × T kernel threads composition). The
    // thread count is process-global, but kernels are bitwise
    // thread-count invariant, so this cannot perturb the other tests in
    // this binary (only their wall-clock); restore the ambient count so
    // a POWERSGD_THREADS=4 CI pass keeps the rest of the suite fanned
    // out.
    let ambient = powersgd::runtime::pool::threads();
    powersgd::runtime::pool::set_threads(4);
    for (name, oracle) in [
        ("powersgd", Box::new(PowerSgd::new(2, 19)) as Box<dyn Compressor>),
        ("unbiased-rank", Box::new(UnbiasedRank::new(2, 19))),
    ] {
        check_equivalence(
            decentralized_by_name(name, 2, 19).unwrap(),
            oracle,
            4,
            3,
            1100,
        );
    }
    powersgd::runtime::pool::set_threads(ambient);
}

#[test]
fn changing_world_size_reinitializes_worker_state() {
    // Like re-building a process group: a different W resets per-worker
    // state, and the result still matches a fresh oracle at that W.
    let mut dec = decentralized_by_name("powersgd", 2, 21).unwrap();
    let mut log = CommLog::default();
    let up4 = rand_updates(4, SHAPES, 900);
    dec.compress_aggregate(&up4, &mut log);

    let up2 = rand_updates(2, SHAPES, 901);
    let d = dec.compress_aggregate(&up2, &mut log);
    let mut fresh = PowerSgd::new(2, 21);
    let o = fresh.compress_aggregate(&up2, &mut log);
    assert_bitwise(&d, &o, 2, "w change 4->2");
}
