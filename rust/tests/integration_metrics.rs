//! Integration: the crate-wide metrics layer (DESIGN.md §15).
//!
//! Four properties are pinned here:
//!
//! 1. **Observation is free of observable effect** — running any engine
//!    with the metrics registry enabled (and the harness emitting
//!    per-step frames) produces *bitwise identical* final parameters to
//!    the same run with everything off, across pipeline modes and
//!    kernel-thread counts.
//! 2. **Reconciliation is exact** — the per-step `wire_sent` deltas a
//!    worker pushes over the sideband sum to precisely the
//!    `MeteredTransport` total the coordinator already audits.
//! 3. **Straggler detection** — a rank with injected per-step jitter is
//!    flagged by `aggregate`, and nobody is flagged on a uniform run.
//! 4. **Dead peers are tolerated** — a rank that pushes no frames shows
//!    up in `missing_ranks`, and the merged summary still renders.
//!
//! The registry mode bit is process-global, so every test that toggles
//! it holds `metrics::registry_lock()` (shared with the in-crate unit
//! tests via the harness, though this binary runs alone).

use powersgd::obs::metrics::{
    aggregate, registry_lock, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S,
};
use powersgd::transport::tcp::{
    coordinate, oracle_trajectory, run_worker_with_metrics, worker_trajectory, HarnessConfig,
    LaunchOutcome, MeteredTransport, Rendezvous, WorkerRunReport,
};
use powersgd::transport::{InProcDuplex, PipelineMode};
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

/// Run a `world`-rank in-process ring over real localhost sockets.
/// `cfg_for_thread` hands each worker thread its own config (rank
/// assignment happens at rendezvous, so per-*rank* targeting must go
/// through `HarnessConfig` fields like `straggle_rank`; per-*thread*
/// configs are still useful for e.g. one metrics-silent worker).
fn run_socket_ring_with(
    world: usize,
    coord_cfg: &HarnessConfig,
    cfg_for_thread: impl Fn(usize) -> HarnessConfig,
) -> LaunchOutcome {
    let rendezvous = Rendezvous::bind("127.0.0.1:0").expect("bind rendezvous");
    let addr = rendezvous.addr().expect("rendezvous addr");
    let workers: Vec<_> = (0..world)
        .map(|i| {
            let addr = addr.clone();
            let cfg = cfg_for_thread(i);
            std::thread::spawn(move || run_worker_with_metrics(&addr, &cfg, TIMEOUT))
        })
        .collect();
    let outcome = coordinate(&rendezvous, world, coord_cfg, TIMEOUT);
    for (idx, handle) in workers.into_iter().enumerate() {
        handle
            .join()
            .expect("worker thread panicked")
            .unwrap_or_else(|e| panic!("worker #{idx}: {e:#}"));
    }
    outcome.unwrap_or_else(|e| panic!("coordinate: {e:#}"))
}

fn run_socket_ring(world: usize, cfg: &HarnessConfig) -> LaunchOutcome {
    run_socket_ring_with(world, cfg, |_| cfg.clone())
}

/// Final parameters of every rank as raw bit patterns, rank-ordered.
fn param_bits(mut reports: Vec<WorkerRunReport>) -> Vec<Vec<u32>> {
    reports.sort_by_key(|r| r.rank);
    reports
        .iter()
        .map(|r| r.params.iter().flat_map(|t| t.data().iter().map(|x| x.to_bits())).collect())
        .collect()
}

/// Drive `world` worker threads over in-process duplex rings and
/// return their run reports (the threaded engine, no sockets).
fn threaded_reports(world: usize, cfg: &HarnessConfig) -> Vec<WorkerRunReport> {
    let endpoints = InProcDuplex::endpoints(world);
    std::thread::scope(|scope| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let cfg = cfg.clone();
                scope.spawn(move || worker_trajectory(MeteredTransport::new(ep), &cfg))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked").expect("worker trajectory"))
            .collect()
    })
}

#[test]
fn metrics_mode_is_bitwise_invisible_to_the_lockstep_oracle() {
    let _guard = registry_lock();
    for pipeline in [PipelineMode::Off, PipelineMode::Delayed] {
        let cfg = HarnessConfig { pipeline, seed: 31, steps: 3, ..HarnessConfig::default() };
        powersgd::obs::enable_metrics(false);
        let (off, logical_off) = oracle_trajectory(4, &cfg).expect("metrics-off oracle");
        powersgd::obs::enable_metrics(true);
        let (on, logical_on) = oracle_trajectory(4, &cfg).expect("metrics-on oracle");
        powersgd::obs::enable_metrics(false);
        assert_eq!(logical_off, logical_on, "logical bytes drifted ({pipeline:?})");
        assert_eq!(off.len(), on.len());
        for (p, (a, b)) in off.iter().zip(on.iter()).enumerate() {
            assert_eq!(a.data(), b.data(), "param[{p}] bits drifted ({pipeline:?})");
        }
    }
}

#[test]
fn metrics_mode_is_bitwise_invisible_on_the_threaded_engine() {
    // The full matrix: pipeline mode × kernel-thread count, metrics-off
    // vs metrics-on (registry enabled AND per-step frames collected).
    let _guard = registry_lock();
    let ambient = powersgd::runtime::pool::threads();
    for pipeline in [PipelineMode::Off, PipelineMode::Overlap, PipelineMode::Delayed] {
        for threads in [1usize, 4] {
            powersgd::runtime::pool::set_threads(threads);
            let base =
                HarnessConfig { pipeline, seed: 37, steps: 3, ..HarnessConfig::default() };

            powersgd::obs::enable_metrics(false);
            let off = param_bits(threaded_reports(4, &base));

            powersgd::obs::enable_metrics(true);
            let on_cfg = HarnessConfig { metrics: true, ..base.clone() };
            let on_reports = threaded_reports(4, &on_cfg);
            powersgd::obs::enable_metrics(false);

            // Reconciliation on the threaded engine: each rank's summed
            // per-step deltas equal its metered totals exactly.
            for r in &on_reports {
                assert_eq!(r.step_metrics.len(), base.steps, "rank {} frame count", r.rank);
                let sent: u64 = r.step_metrics.iter().map(|m| m.wire_sent).sum();
                assert_eq!(sent, r.wire_bytes, "rank {} wire_sent deltas", r.rank);
            }

            let on = param_bits(on_reports);
            assert_eq!(off, on, "params drifted ({pipeline:?}, {threads} kernel threads)");
        }
    }
    powersgd::runtime::pool::set_threads(ambient);
}

#[test]
fn socket_launch_reconciles_metrics_frames_exactly() {
    let cfg = HarnessConfig { metrics: true, seed: 41, steps: 3, ..HarnessConfig::default() };
    let outcome = run_socket_ring(3, &cfg);
    assert!(outcome.reports.iter().all(|r| r.bitwise), "non-bitwise report with metrics on");
    assert_eq!(outcome.metrics_reconcile(), Some(true), "sideband frames must sum to metered");

    for (rank, frames) in outcome.metrics_by_rank.iter().enumerate() {
        assert_eq!(frames.len(), cfg.steps, "rank {rank} frame count");
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.rank, rank as u64, "frame rank tag");
            assert_eq!(f.step, i as u64, "frame step ordering");
            assert!(f.step_seconds >= 0.0 && f.step_seconds.is_finite());
            assert!(f.approx_error.is_finite());
        }
        let sent: u64 = frames.iter().map(|f| f.wire_sent).sum();
        assert_eq!(sent, outcome.reports[rank].wire_bytes, "rank {rank} wire_sent total");
    }

    let health = aggregate(&outcome.metrics_by_rank, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S);
    assert_eq!(health.world, 3);
    assert!(health.missing_ranks.is_empty(), "all ranks reported");
    assert_eq!(health.steps.len(), cfg.steps);
    let metered_total: u64 = outcome.reports.iter().map(|r| r.wire_bytes).sum();
    assert_eq!(health.wire_sent_total, metered_total, "merged summary wire total");
}

#[test]
fn metrics_off_run_has_an_empty_sideband() {
    // `metrics: false` workers push nothing; the coordinator sees empty
    // streams and `metrics_reconcile` abstains rather than reporting a
    // vacuous success.
    let cfg = HarnessConfig { seed: 43, steps: 2, ..HarnessConfig::default() };
    let outcome = run_socket_ring(2, &cfg);
    assert!(outcome.reports.iter().all(|r| r.bitwise));
    assert!(outcome.metrics_by_rank.iter().all(|f| f.is_empty()));
    assert_eq!(outcome.metrics_reconcile(), None);
}

#[test]
fn straggler_is_flagged_in_a_jittered_run_and_nobody_in_a_uniform_one() {
    // Jittered: rank 1 sleeps 600 ms inside every timed step — far past
    // the default `max(2×median, median + 20 ms)` threshold even on a
    // heavily loaded CI box, where the fast rank's tiny model step
    // stays well under 300 ms.
    let jittered = HarnessConfig {
        metrics: true,
        straggle_rank: 1,
        straggle_ms: 600,
        seed: 47,
        steps: 2,
        ..HarnessConfig::default()
    };
    let outcome = run_socket_ring(2, &jittered);
    assert!(outcome.reports.iter().all(|r| r.bitwise), "jitter must not change the trajectory");
    assert_eq!(outcome.metrics_reconcile(), Some(true));
    let health = aggregate(&outcome.metrics_by_rank, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S);
    assert_eq!(health.straggler_ranks(), vec![1], "only the jittered rank is flagged");
    for s in &health.steps {
        assert!(
            s.median_step_s < 0.6,
            "median tracked the fast rank, not the straggler: {}",
            s.median_step_s
        );
        assert!(s.p95_step_s >= 0.6, "p95 tracked the straggler: {}", s.p95_step_s);
    }

    // Uniform: same run without injection. Real timings on a shared
    // test box can hiccup by tens of milliseconds, so use a generous
    // absolute slack — the *relative* factor is what a uniform run
    // must not trip.
    let uniform = HarnessConfig { metrics: true, seed: 47, steps: 2, ..HarnessConfig::default() };
    let outcome = run_socket_ring(2, &uniform);
    let health = aggregate(&outcome.metrics_by_rank, STRAGGLER_FACTOR, 0.25);
    assert!(
        health.straggler_ranks().is_empty(),
        "uniform run flagged {:?}",
        health.straggler_ranks()
    );
}

#[test]
fn dead_peer_is_tolerated_in_the_merged_summary() {
    // One worker thread runs metrics-silent (frames are gated on its
    // *own* config); whichever rank it lands on becomes a dead peer in
    // the sideband. The merged summary must report it in
    // `missing_ranks` instead of failing, and the live ranks must still
    // reconcile exactly.
    let on = HarnessConfig { metrics: true, seed: 53, steps: 2, ..HarnessConfig::default() };
    let silent = HarnessConfig { metrics: false, ..on.clone() };
    let outcome = run_socket_ring_with(3, &on, |i| if i == 1 { silent.clone() } else { on.clone() });
    assert!(outcome.reports.iter().all(|r| r.bitwise), "mixed metrics configs stay bitwise");
    // Tolerant reconcile: empty streams are skipped, live ones checked.
    assert_eq!(outcome.metrics_reconcile(), Some(true));

    let health = aggregate(&outcome.metrics_by_rank, STRAGGLER_FACTOR, STRAGGLER_MIN_EXCESS_S);
    assert_eq!(health.missing_ranks.len(), 1, "exactly one dead peer");
    let dead = health.missing_ranks[0];
    for s in &health.steps {
        assert!(!s.ranks.contains(&dead), "dead peer cannot appear in step health");
        assert_eq!(s.ranks.len(), 2, "both live ranks reported");
    }
    let doc = health.to_json(outcome.metrics_reconcile());
    assert!(doc.contains(&format!("\"missing_ranks\": [{dead}]")), "summary snapshot:\n{doc}");
    assert!(doc.contains("\"reconciles_metered\": true"), "summary snapshot:\n{doc}");
}

/// End-to-end acceptance: a real 2-process `launch --metrics` writes
/// one JSONL per rank plus the merged summary, and the summary records
/// exact reconciliation against the metered transport. Rides the same
/// binary the TCP suite exercises.
#[test]
fn multiprocess_launch_writes_metrics_artifacts() {
    let exe = env!("CARGO_BIN_EXE_powersgd");
    let dir = std::env::temp_dir().join(format!("powersgd-metrics-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("scratch dir");
    let output = std::process::Command::new(exe)
        .current_dir(&dir)
        .args([
            "launch",
            "--workers",
            "2",
            "--transport",
            "tcp",
            "--compressor",
            "powersgd",
            "--rank",
            "2",
            "--steps",
            "3",
            "--seed",
            "7",
            "--metrics",
            "METRICS.json",
            "--straggle-rank",
            "1",
            "--straggle-ms",
            "300",
        ])
        .output()
        .expect("spawning powersgd launch");
    let stdout = String::from_utf8_lossy(&output.stdout);
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(
        output.status.success(),
        "launch --metrics failed ({}):\nstdout:\n{stdout}\nstderr:\n{stderr}",
        output.status
    );
    assert!(
        stdout.contains("bitwise-identical to the lockstep oracle"),
        "launch --metrics: missing verification line in:\n{stdout}"
    );

    let merged = std::fs::read_to_string(dir.join("METRICS.json")).expect("merged METRICS.json");
    assert!(merged.contains("\"world\": 2"), "merged summary:\n{merged}");
    assert!(merged.contains("\"missing_ranks\": []"), "merged summary:\n{merged}");
    assert!(merged.contains("\"reconciles_metered\": true"), "merged summary:\n{merged}");
    assert!(merged.contains("\"straggler_ranks\": [1]"), "merged summary:\n{merged}");

    for rank in 0..2 {
        let path = dir.join(format!("METRICS_r{rank}.jsonl"));
        let jsonl = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("missing {}: {e}", path.display()));
        assert_eq!(jsonl.lines().count(), 3, "rank {rank}: one record per step");
        for line in jsonl.lines() {
            assert!(
                line.starts_with('{') && line.ends_with('}'),
                "rank {rank}: malformed JSONL line: {line}"
            );
            assert!(line.contains(&format!("\"rank\": {rank}")), "rank tag: {line}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}
