//! Property-based tests over the coordinator's core invariants.
//!
//! No proptest crate offline — properties are checked over seeded random
//! sweeps (many shapes × worker counts × ranks per property), which is
//! what proptest would generate, minus shrinking.
//!
//! Every test serializes on one lock: the kernel-scratch growth counter
//! pinned by `prop_kernel_scratch_zero_alloc_after_first_step` is
//! process-global, and each concurrently running test executes on a
//! fresh harness thread whose thread-local kernel scratch would grow on
//! first use — right in the middle of the measurement window.

use powersgd::collectives::{ring_all_reduce_sum, CommLog};
use powersgd::compress::{
    Compressor, Locals, PowerSgd, RandomK, SchemeMeta, SignNorm, TopK, UnbiasedRank,
};
use powersgd::grad::ParamRegistry;
use powersgd::linalg::{gram_schmidt_in_place, orthonormal_error, svd};
use powersgd::runtime::pool::{kernel_scratch_grows, set_threads, threads};
use powersgd::tensor::{matmul, Tensor};
use powersgd::util::Rng;
use std::sync::{Mutex, MutexGuard};

static SERIAL: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

fn rand_dims(rng: &mut Rng) -> (usize, usize, usize) {
    let n = 2 + rng.below(40) as usize;
    let m = 2 + rng.below(40) as usize;
    let r = 1 + rng.below(4.min(n.min(m) as u64)) as usize;
    (n, m, r)
}

/// Property: PowerSGD linearity (Lemma 3) — compress+aggregate over W
/// workers equals compressing the mean update, for random shapes/W.
#[test]
fn prop_powersgd_linearity() {
    let _g = lock();
    let mut rng = Rng::new(101);
    for case in 0..25 {
        let (n, m, r) = rand_dims(&mut rng);
        let w = 1 + rng.below(8) as usize;
        let updates: Vec<Vec<Tensor>> =
            (0..w).map(|_| vec![rand_tensor(&[n, m], &mut rng)]).collect();
        let mut mean = Tensor::zeros(&[n, m]);
        for wu in &updates {
            mean.axpy(1.0 / w as f32, &wu[0]);
        }
        let mut multi = PowerSgd::new(r, case);
        let mut single = PowerSgd::new(r, case);
        let mut log = CommLog::default();
        let a = multi.compress_aggregate(&updates, &mut log);
        let b = single.compress_aggregate(&[vec![mean]], &mut log);
        assert!(
            a.mean[0].allclose(&b.mean[0], 1e-2, 1e-3),
            "case {case} (n={n} m={m} r={r} w={w}): diff {}",
            a.mean[0].max_abs_diff(&b.mean[0])
        );
    }
}

/// Property: unbiased rank-r is linear too.
#[test]
fn prop_unbiased_linearity() {
    let _g = lock();
    let mut rng = Rng::new(102);
    for case in 0..15 {
        let (n, m, r) = rand_dims(&mut rng);
        let w = 1 + rng.below(5) as usize;
        let updates: Vec<Vec<Tensor>> =
            (0..w).map(|_| vec![rand_tensor(&[n, m], &mut rng)]).collect();
        let mut mean = Tensor::zeros(&[n, m]);
        for wu in &updates {
            mean.axpy(1.0 / w as f32, &wu[0]);
        }
        let mut multi = UnbiasedRank::new(r, case);
        let mut single = UnbiasedRank::new(r, case);
        let mut log = CommLog::default();
        let a = multi.compress_aggregate(&updates, &mut log);
        let b = single.compress_aggregate(&[vec![mean]], &mut log);
        assert!(a.mean[0].allclose(&b.mean[0], 1e-2, 1e-3), "case {case}");
    }
}

/// Property: ring all-reduce == naive sum for arbitrary W and lengths,
/// including lengths smaller than W.
#[test]
fn prop_ring_allreduce_equals_naive() {
    let _g = lock();
    let mut rng = Rng::new(103);
    for _ in 0..40 {
        let w = 1 + rng.below(12) as usize;
        let n = 1 + rng.below(300) as usize;
        let bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut expect = vec![0.0f32; n];
        for b in &bufs {
            for (e, v) in expect.iter_mut().zip(b) {
                *e += v;
            }
        }
        let mut got = bufs.clone();
        ring_all_reduce_sum(&mut got);
        for b in &got {
            for (g, e) in b.iter().zip(&expect) {
                assert!((g - e).abs() <= 1e-4 * e.abs().max(1.0), "w={w} n={n}");
            }
        }
    }
}

/// Property: EF memory identity — for per-worker compressors, the local
/// reconstruction plus the retained error reproduces the worker's update
/// exactly.
#[test]
fn prop_error_feedback_identity() {
    let _g = lock();
    let mut rng = Rng::new(104);
    for case in 0..15 {
        let (n, m, r) = rand_dims(&mut rng);
        let w = 2 + rng.below(4) as usize;
        let updates: Vec<Vec<Tensor>> =
            (0..w).map(|_| vec![rand_tensor(&[n, m], &mut rng)]).collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(RandomK::new(r, case)),
            Box::new(TopK::new(r)),
            Box::new(SignNorm::new()),
        ];
        for mut comp in comps {
            let mut log = CommLog::default();
            let agg = comp.compress_aggregate(&updates, &mut log);
            if let Locals::PerWorker(ref locals) = agg.locals {
                for (wu, lw) in updates.iter().zip(locals.iter()) {
                    let err = wu[0].sub(&lw[0]);
                    let recon = err.add(&lw[0]);
                    assert!(
                        recon.allclose(&wu[0], 1e-5, 1e-5),
                        "{} case {case}",
                        comp.name()
                    );
                }
            } else {
                panic!("{} should produce per-worker locals", comp.name());
            }
        }
    }
}

/// Property: Gram–Schmidt output is orthonormal and spans the input.
#[test]
fn prop_gram_schmidt_orthonormal() {
    let _g = lock();
    let mut rng = Rng::new(105);
    for _ in 0..30 {
        let n = 2 + rng.below(200) as usize;
        let r = 1 + rng.below(6.min(n as u64)) as usize;
        let mut p = rand_tensor(&[n, r], &mut rng);
        let orig = p.clone();
        gram_schmidt_in_place(&mut p);
        assert!(orthonormal_error(&p) < 1e-3, "n={n} r={r}");
        // span preserved: orig = P (Pᵀ orig) exactly for full-rank input
        let coeffs = powersgd::tensor::matmul_at_b(&p, &orig);
        let recon = matmul(&p, &coeffs);
        assert!(
            recon.allclose(&orig, 5e-2, 5e-2),
            "span lost: diff {}",
            recon.max_abs_diff(&orig)
        );
    }
}

/// Property: SVD reconstructs and is ordered, on random rectangles.
#[test]
fn prop_svd_reconstruction() {
    let _g = lock();
    let mut rng = Rng::new(106);
    for _ in 0..20 {
        let n = 2 + rng.below(24) as usize;
        let m = 2 + rng.below(24) as usize;
        let a = rand_tensor(&[n, m], &mut rng);
        let d = svd(&a);
        let rec = d.reconstruct(n.min(m));
        assert!(
            rec.allclose(&a, 5e-3, 5e-3),
            "n={n} m={m} diff {}",
            rec.max_abs_diff(&a)
        );
        for wpair in d.s.windows(2) {
            assert!(wpair[0] >= wpair[1] - 1e-5);
        }
    }
}

/// Property: byte accounting equals the closed-form message size for
/// every compressor on random registries.
#[test]
fn prop_bytes_match_closed_form() {
    let _g = lock();
    let mut rng = Rng::new(107);
    for case in 0..10 {
        let (n, m, r) = rand_dims(&mut rng);
        let vlen = 1 + rng.below(16) as usize;
        let reg = ParamRegistry::from_shapes(&[("w", vec![n, m]), ("b", vec![vlen])]);
        let w = 2 + rng.below(4) as usize;
        let updates: Vec<Vec<Tensor>> = (0..w)
            .map(|_| vec![rand_tensor(&[n, m], &mut rng), rand_tensor(&[vlen], &mut rng)])
            .collect();
        let comps: Vec<Box<dyn Compressor>> = vec![
            Box::new(PowerSgd::new(r, case)),
            Box::new(UnbiasedRank::new(r, case)),
            Box::new(RandomK::new(r, case)),
            Box::new(TopK::new(r)),
            Box::new(SignNorm::new()),
        ];
        for mut comp in comps {
            let mut log = CommLog::default();
            comp.compress_aggregate(&updates, &mut log);
            assert_eq!(
                log.bytes_sent(),
                comp.message_bytes(&reg),
                "{} case {case} (n={n} m={m} r={r})",
                comp.name()
            );
        }
    }
}

/// Property: PowerSGD output rank never exceeds r.
#[test]
fn prop_powersgd_output_rank_bounded() {
    let _g = lock();
    let mut rng = Rng::new(108);
    for case in 0..10 {
        let (n, m, r) = rand_dims(&mut rng);
        if r >= n.min(m) {
            continue;
        }
        let updates = vec![vec![rand_tensor(&[n, m], &mut rng)]];
        let mut comp = PowerSgd::new(r, case);
        let mut log = CommLog::default();
        let out = comp.compress_aggregate(&updates, &mut log).mean[0].clone();
        let d = svd(&out);
        let tail = d.s[r];
        assert!(
            tail < 1e-3 * d.s[0].max(1e-9),
            "case {case}: rank leak, sv[{r}]={tail} vs sv[0]={}",
            d.s[0]
        );
    }
}

/// Property: the blocked kernels' per-thread scratch — packed GEMM
/// panels, accumulator tiles, Gram–Schmidt reduction partials — reaches
/// steady state on the first step. `kernel_scratch_grows()` must not
/// move across steps 2+ of a shape-stable PowerSGD workload, at every
/// thread count (DESIGN.md §11 zero-alloc leg).
///
/// Sound because (a) this binary's tests are serialized on [`lock`], so
/// nothing else touches kernels during the window, and (b) the pool's
/// chunk→helper assignment is a pure function of (chunks, threads), so
/// the warm step exercises exactly the threads (with exactly the
/// per-thread scratch lengths) the measured steps will.
#[test]
fn prop_kernel_scratch_zero_alloc_after_first_step() {
    let _g = lock();
    let ambient = threads();
    // Tall matrix (multi-chunk GS reductions), square-ish, and tiny —
    // same mix as the bitwise-invariance workload.
    let shapes: [&[usize]; 3] = [&[4500, 64], &[64, 80], &[12, 8]];
    let updates_for = |seed: u64| -> Vec<Vec<Tensor>> {
        let mut rng = Rng::new(seed);
        (0..2).map(|_| shapes.iter().map(|s| rand_tensor(s, &mut rng)).collect()).collect()
    };
    for &t in &[1usize, 2, 4, 8] {
        set_threads(t);
        let mut comp = PowerSgd::new(2, 77);
        let mut log = CommLog::default();
        // Step 1 may grow: first touch of this test thread's slots and
        // of any pool helper newly participating at this count.
        comp.compress_aggregate(&updates_for(5000), &mut log);
        let warmed = kernel_scratch_grows();
        for step in 0..3u64 {
            comp.compress_aggregate(&updates_for(5001 + step), &mut log);
            assert_eq!(
                kernel_scratch_grows(),
                warmed,
                "kernel scratch grew after warm-up at t={t}, step {step}"
            );
        }
    }
    set_threads(ambient);
}
