//! Paper Appendix D (Tables 8/9, Figure 6): transformer language
//! modeling at ranks 4..32 with 32 workers — compression ratio and
//! simulated training-time reproduction, plus a short real training run
//! of the tiny preset across ranks (validation-loss ordering).

mod common;

use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::LmCorpus;
use powersgd::net::NCCL;
use powersgd::optim::{EfSgd, LrSchedule, Sgd};
use powersgd::profiles::transformer_wikitext103;
use powersgd::runtime::Runtime;
use powersgd::simulate::{simulate_step, Scheme};
use powersgd::util::Table;

fn train_tiny(dir: &str, rank: Option<usize>, steps: usize) -> f64 {
    let mut rt = Runtime::cpu(dir).unwrap();
    let train = rt.load("transformer_tiny_train").unwrap();
    let eval = rt.load("transformer_tiny_eval").unwrap();
    let opt: Box<dyn powersgd::optim::DistOptimizer> = match rank {
        None => Box::new(Sgd::new(LrSchedule::paper_step(0.01, 2, 0, vec![]), 0.9)),
        Some(r) => Box::new(EfSgd::new(
            Box::new(PowerSgd::new(r, 1)),
            LrSchedule::paper_step(0.01, 2, 0, vec![]),
            0.9,
        )),
    };
    let cfg = TrainerConfig { workers: 2, eval_kind: EvalKind::Perplexity, ..Default::default() };
    let mut data = LmCorpus::new(2000, 8, 64, 2, 42);
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg).unwrap();
    trainer.train(&mut data, steps).unwrap();
    trainer.evaluate(&mut data).unwrap().ln() // validation loss
}

fn main() {
    // --- Table 9: compression ratio + simulated time at paper scale ---
    let prof = transformer_wikitext103();
    let sgd = simulate_step(&prof, Scheme::Sgd, 32, &NCCL);
    // paper: 20h for 17875 updates uncompressed
    let paper_hours = |step_s: f64| step_s * 17875.0 / 3600.0;
    let mut table = Table::new(
        "Table 9 — Transformer/WikiText-103, 32 workers (simulated)",
        &["Compression", "Ratio", "Time/step", "Total (17875 updates)"],
    );
    table.row(&[
        "Uncompressed".into(),
        "1x".into(),
        format!("{:.1} s", sgd.total()),
        format!("{:.0} h", paper_hours(sgd.total())),
    ]);
    for rank in [4usize, 8, 16, 32] {
        let b = simulate_step(&prof, Scheme::PowerSgd { rank }, 32, &NCCL);
        let ratio = prof.registry.compression_ratio(rank);
        table.row(&[
            format!("Rank {rank}"),
            format!("{ratio:.0}x"),
            format!("{:.1} s", b.total()),
            format!("{:.0} h", paper_hours(b.total())),
        ]);
    }
    table.print();
    println!("paper: 20h uncompressed -> 11-13h at ranks 4-32; ratios 105x..14x\n");

    // --- Figure 6 analogue: rank sweep on the tiny preset (real run) ---
    let Some(dir) = common::artifacts_dir() else { return };
    let steps = 60;
    let mut t = Table::new(
        "Figure 6 analogue — validation loss after short training (tiny preset)",
        &["Algorithm", "Val loss"],
    );
    let base = train_tiny(&dir, None, steps);
    t.row(&["SGD".into(), format!("{base:.3}")]);
    for rank in [1usize, 4, 16] {
        let l = train_tiny(&dir, Some(rank), steps);
        t.row(&[format!("Rank {rank}"), format!("{l:.3}")]);
    }
    t.print();
    println!("\npaper shape: higher rank closes the gap to uncompressed SGD.");
}
