//! Paper Figure 3: scaling of PowerSGD vs SGD vs Signum on NCCL and
//! GLOO backends. Batch size grows with W; we report one-epoch speedup
//! over 1-worker SGD (log-log series the paper plots).

mod common;

use powersgd::net::{GLOO, NCCL};
use powersgd::profiles::resnet18;
use powersgd::simulate::{epoch_speedup_vs_single_sgd, Scheme};
use powersgd::util::Table;

fn main() {
    let prof = resnet18();
    for backend in [NCCL, GLOO] {
        let mut table = Table::new(
            &format!("Figure 3 — epoch speedup vs 1-worker SGD ({})", backend.name),
            &["Workers", "SGD", "PowerSGD rank 2", "Signum"],
        );
        for w in [1usize, 2, 4, 8, 16, 32] {
            let sg = epoch_speedup_vs_single_sgd(&prof, Scheme::Sgd, w, &backend);
            let pw = epoch_speedup_vs_single_sgd(&prof, Scheme::PowerSgd { rank: 2 }, w, &backend);
            let si = epoch_speedup_vs_single_sgd(&prof, Scheme::Signum, w, &backend);
            table.row(&[
                format!("{w}"),
                format!("{sg:.1}x"),
                format!("{pw:.1}x"),
                format!("{si:.1}x"),
            ]);
        }
        table.print();
        println!();
    }
    println!("paper shape: all scale on NCCL (Signum sub-linearly);");
    println!("on GLOO only PowerSGD retains near-linear scaling.");
}
