//! Paper Table 2: warm start vs cold start vs best rank-2 approximation.
//!
//! Paper: best approximation 94.4% · warm start (default) 94.4% ·
//! without warm start 94.0%. Ours: convnet proxy accuracy ordering plus
//! the *approximation-quality* mechanism measured directly (relative
//! Frobenius error tracking a slowly-drifting gradient matrix).

mod common;

use powersgd::collectives::CommLog;
use powersgd::compress::{BestRankR, Compressor, PowerSgd};
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule};
use powersgd::tensor::Tensor;
use powersgd::util::{Rng, Table};

fn approx_error(mut comp: Box<dyn Compressor>, drift: f32, steps: usize) -> f64 {
    let mut rng = Rng::new(77);
    let mut base = Tensor::zeros(&[64, 48]);
    rng.fill_normal(base.data_mut(), 1.0);
    let mut log = CommLog::default();
    let mut total = 0.0;
    for _ in 0..steps {
        let mut d = Tensor::zeros(&[64, 48]);
        rng.fill_normal(d.data_mut(), drift);
        base.axpy(1.0, &d);
        let out = comp.compress_aggregate(&[vec![base.clone()]], &mut log);
        total += base.sub(&out.mean[0]).norm() / base.norm();
    }
    total / steps as f64
}

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };
    let lr = || LrSchedule::paper_step(0.01, 4, 0, vec![]);
    let cases: Vec<(&str, Box<dyn DistOptimizer>)> = vec![
        (
            "Best approximation",
            Box::new(EfSgd::new(Box::new(BestRankR::new(2, 1)), lr(), 0.9)),
        ),
        (
            "Warm start (default)",
            Box::new(EfSgd::new(Box::new(PowerSgd::new(2, 1)), lr(), 0.9)),
        ),
        (
            "Without warm start",
            Box::new(EfSgd::new(Box::new(PowerSgd::new(2, 1).without_warm_start()), lr(), 0.9)),
        ),
    ];
    let mut table = Table::new(
        "Table 2 — best rank-2 approximation vs PowerSGD (proxy accuracy)",
        &["Algorithm", "Test accuracy", "Rel. approx error (drifting M)"],
    );
    for (name, opt) in cases {
        let (acc, _) = common::run_convnet(&dir, opt, 4, 300, 42);
        let comp: Box<dyn Compressor> = match name {
            "Best approximation" => Box::new(BestRankR::new(2, 1)),
            "Warm start (default)" => Box::new(PowerSgd::new(2, 1)),
            _ => Box::new(PowerSgd::new(2, 1).without_warm_start()),
        };
        let err = approx_error(comp, 0.05, 40);
        table.row(&[name.to_string(), format!("{acc:.1}%"), format!("{err:.4}")]);
    }
    table.print();
    println!("\nexpected ordering: warm-start error ≈ best-approximation error < cold-start error");
}
