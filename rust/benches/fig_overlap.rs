//! Comm/compute overlap on the threaded engine: simulated step time for
//! bucketed, overlapped collectives vs. the sequential (no-overlap)
//! schedule — the system-level effect Agarwal et al. and Zhang et al.
//! show dominates end-to-end speedup (PAPERS.md).
//!
//! Companion to `fig3_scaling`: same α–β cluster, but the schedule now
//! matters. PowerSGD rank 2 with 4 MB buckets must beat its no-overlap
//! configuration at every W — and overlap also helps plain SGD, which
//! shrinks (but does not erase) compression's edge.
//!
//! Emits `BENCH_fig_overlap.json` (one record per scheme × backend × W)
//! for the CI `bench-smoke` artifact trail. `BENCH_QUICK=1` restricts
//! the sweep to NCCL.

use powersgd::collectives::{ring_wire_bytes, CollKind};
use powersgd::grad::ParamRegistry;
use powersgd::net::{GLOO, NCCL};
use powersgd::profiles::resnet18;
use powersgd::simulate::{simulate_step_overlapped, Scheme};
use powersgd::transport::Cluster;
use powersgd::util::{quick_mode, BenchJson, Table};

const BUCKET_BYTES: u64 = 4 << 20; // DDP-ish 4 MB buckets

/// The per-step collective ops a decentralized worker round issues for
/// `scheme` (mirrors `compress/worker.rs`): vectors travel in one
/// packed all-reduce, matrix traffic uses the scheme's own collective,
/// and PowerSGD splits into separate P and Q all-reduces. Feeding each
/// op through `ring_wire_bytes` reproduces exactly what a metered
/// transport counts — not just the single-collective approximation.
fn worker_round_ops(scheme: Scheme, reg: &ParamRegistry) -> Vec<(CollKind, u64)> {
    let vec_bytes: u64 =
        reg.specs.iter().filter(|s| s.matrix_dims().is_none()).map(|s| s.bytes()).sum();
    let mat_msg: u64 = reg
        .specs
        .iter()
        .filter(|s| s.matrix_dims().is_some())
        .map(|s| scheme.spec_message_bytes(s))
        .sum();
    let mut ops = Vec::new();
    match scheme {
        // Identity compression packs everything into one all-reduce.
        Scheme::Sgd => ops.push((CollKind::AllReduce, vec_bytes + mat_msg)),
        Scheme::PowerSgd { rank } => {
            if vec_bytes > 0 {
                ops.push((CollKind::AllReduce, vec_bytes));
            }
            let p: u64 = reg
                .specs
                .iter()
                .filter_map(|s| s.matrix_dims())
                .map(|(n, _)| (n * rank * 4) as u64)
                .sum();
            let q: u64 = reg
                .specs
                .iter()
                .filter_map(|s| s.matrix_dims())
                .map(|(_, m)| (m * rank * 4) as u64)
                .sum();
            ops.push((CollKind::AllReduce, p));
            ops.push((CollKind::AllReduce, q));
        }
        _ => {
            // Gather schemes: vectors still all-reduce uncompressed;
            // only the packed matrix messages are gathered.
            if vec_bytes > 0 {
                ops.push((CollKind::AllReduce, vec_bytes));
            }
            ops.push((if scheme.all_reduce() { CollKind::AllReduce } else { CollKind::AllGather }, mat_msg));
        }
    }
    ops
}

fn main() {
    let prof = resnet18();
    let schemes = [Scheme::Sgd, Scheme::PowerSgd { rank: 2 }, Scheme::SignNorm];
    let backends = if quick_mode() {
        vec![NCCL]
    } else {
        vec![NCCL, GLOO]
    };
    let mut json = BenchJson::new("fig_overlap");
    // This bench models the threaded engine's bucketed schedule over
    // in-process rings; tag the trajectory so it stays comparable with
    // lockstep and tcp runs of the same cases. (BenchJson records the
    // ambient kernel thread count automatically — simulation-only
    // here, but it keeps the schema aligned with kernel_hotpath.)
    json.set_context("threaded", "inproc");
    // The document models the bucketed overlapped schedule; every
    // record still carries the sequential baseline (`no_overlap_ms`)
    // next to `overlapped_ms`, so both schedules stay in one artifact.
    json.set_pipeline("overlap");

    for backend in backends {
        for scheme in schemes {
            let mut table = Table::new(
                &format!(
                    "Overlap — {} on {}, 4 MB buckets ({})",
                    scheme.name(),
                    prof.name,
                    backend.name
                ),
                &["Workers", "No overlap", "Overlapped", "Comm exposed", "Saved"],
            );
            for w in [4usize, 8, 16] {
                let cluster = Cluster::uniform(w, &backend);
                let seq = simulate_step_overlapped(&prof, scheme, &cluster, BUCKET_BYTES, false);
                let ovl = simulate_step_overlapped(&prof, scheme, &cluster, BUCKET_BYTES, true);
                assert!(
                    ovl.total < seq.total,
                    "{} W={w}: overlapped {:.1} ms !< sequential {:.1} ms",
                    scheme.name(),
                    ovl.total * 1e3,
                    seq.total * 1e3
                );
                table.row(&[
                    format!("{w}"),
                    format!("{:.0} ms", seq.total * 1e3),
                    format!("{:.0} ms", ovl.total * 1e3),
                    format!("{:.1} ms", ovl.exposed_comm * 1e3),
                    format!("{:.0}%", 100.0 * (1.0 - ovl.total / seq.total)),
                ]);
                // Byte columns: the logical per-worker message plus the
                // exact ring expansion a metered transport would count
                // (rank 0's share, summed over the round's collectives;
                // even splits make ranks identical).
                let msg = scheme.message_bytes(&prof.registry);
                let wire: u64 = worker_round_ops(scheme, &prof.registry)
                    .iter()
                    .map(|&(kind, bytes)| ring_wire_bytes(kind, bytes, w, 0))
                    .sum();
                json.record(
                    &format!("{}/{}/w{}", backend.name, scheme.name(), w),
                    &[
                        ("no_overlap_ms", seq.total * 1e3),
                        ("overlapped_ms", ovl.total * 1e3),
                        ("exposed_comm_ms", ovl.exposed_comm * 1e3),
                        ("saved_pct", 100.0 * (1.0 - ovl.total / seq.total)),
                        ("logical_bytes", msg as f64),
                        ("wire_bytes", wire as f64),
                    ],
                );
            }
            table.print();
            println!();
        }
    }

    // Straggler scenario: one worker 1.5× slower gates every collective;
    // overlap still hides the network but cannot hide the slow compute.
    let mut table = Table::new(
        "Straggler — PowerSGD rank 2, 16 workers, NCCL, 4 MB buckets",
        &["Slowdown", "No overlap", "Overlapped", "Comm exposed"],
    );
    for slowdown in [1.0f64, 1.25, 1.5, 2.0] {
        let cluster = Cluster::with_straggler(16, &NCCL, slowdown);
        let scheme = Scheme::PowerSgd { rank: 2 };
        let seq = simulate_step_overlapped(&prof, scheme, &cluster, BUCKET_BYTES, false);
        let ovl = simulate_step_overlapped(&prof, scheme, &cluster, BUCKET_BYTES, true);
        table.row(&[
            format!("×{slowdown:.2}"),
            format!("{:.0} ms", seq.total * 1e3),
            format!("{:.0} ms", ovl.total * 1e3),
            format!("{:.1} ms", ovl.exposed_comm * 1e3),
        ]);
        json.record(
            &format!("straggler/x{slowdown:.2}"),
            &[("no_overlap_ms", seq.total * 1e3), ("overlapped_ms", ovl.total * 1e3)],
        );
    }
    table.print();
    println!();
    println!("shape: overlap strictly beats no-overlap at every W (asserted);");
    println!("it helps SGD too — compression's edge shrinks but survives on GLOO.");
    json.write().expect("write BENCH_fig_overlap.json");
}
