//! Paper Table 7: language modeling — SGD vs Signum vs rank-4 PowerSGD.
//! Paper: perplexity 91/142/91; time/batch 300/424/134 ms (−55%).

mod common;

use powersgd::compress::PowerSgd;
use powersgd::net::NCCL;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd, SignumOpt};
use powersgd::profiles::lstm_wikitext2;
use powersgd::simulate::{data_per_epoch_mb, simulate_step, Scheme};
use powersgd::util::Table;

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };
    let prof = lstm_wikitext2();
    let cases: Vec<(&str, Box<dyn DistOptimizer>, Scheme)> = vec![
        ("SGD", Box::new(Sgd::new(LrSchedule::paper_step(0.125, 4, 0, vec![]), 0.9)), Scheme::Sgd),
        ("Signum", Box::new(SignumOpt::new(LrSchedule::paper_step(0.005, 4, 0, vec![]), 0.9)), Scheme::Signum),
        (
            "Rank 4",
            Box::new(EfSgd::new(Box::new(PowerSgd::new(4, 1)), LrSchedule::paper_step(0.125, 4, 0, vec![]), 0.9)),
            Scheme::PowerSgd { rank: 4 },
        ),
    ];
    let sgd_total = simulate_step(&prof, Scheme::Sgd, 16, &NCCL).total();
    let mut table = Table::new(
        "Table 7 — LSTM language modeling (WikiText-proxy)",
        &["Algorithm", "Perplexity (proxy)", "Data/epoch", "Time/batch (sim)", "vs SGD"],
    );
    for (name, opt, scheme) in cases {
        let (ppl, _) = common::run_lstm(&dir, opt, 4, 200, 42);
        let b = simulate_step(&prof, scheme, 16, &NCCL);
        table.row(&[
            name.to_string(),
            format!("{ppl:.1}"),
            format!("{:.0} MB", data_per_epoch_mb(&prof, scheme)),
            format!("{:.0} ms", b.total() * 1e3),
            format!("{:+.0}%", (b.total() / sgd_total - 1.0) * 100.0),
        ]);
    }
    table.print();
    println!("\npaper shape: rank-4 matches SGD perplexity with ~55% less time; Signum slower AND worse.");
}
