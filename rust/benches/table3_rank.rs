//! Paper Table 3: PowerSGD with varying rank, ResNet18/CIFAR10 and
//! LSTM/WikiText-2 — accuracy (proxy training), data/epoch (exact paper
//! shapes) and time/batch (calibrated simulator). Also prints the
//! per-layer compression table (paper Tables 10/11) and writes the
//! convergence-curve CSV backing Figure 4.

mod common;

use powersgd::compress::PowerSgd;
use powersgd::net::NCCL;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd};
use powersgd::profiles::{lstm_wikitext2, resnet18};
use powersgd::simulate::{data_per_epoch_mb, simulate_step, Scheme};
use powersgd::util::Table;

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };

    // ---- image classification side -------------------------------
    let prof = resnet18();
    let sgd_total = simulate_step(&prof, Scheme::Sgd, 16, &NCCL).total();
    let mut table = Table::new(
        "Table 3a — PowerSGD rank sweep, ResNet18/CIFAR10",
        &["Algorithm", "Test acc (proxy)", "Data/epoch", "Time/batch", "vs SGD"],
    );
    let schemes = [
        ("SGD", Scheme::Sgd, None),
        ("Rank 1", Scheme::PowerSgd { rank: 1 }, Some(1)),
        ("Rank 2", Scheme::PowerSgd { rank: 2 }, Some(2)),
        ("Rank 4", Scheme::PowerSgd { rank: 4 }, Some(4)),
    ];
    for (name, scheme, rank) in schemes {
        let opt: Box<dyn DistOptimizer> = match rank {
            None => Box::new(Sgd::new(LrSchedule::paper_step(0.01, 4, 0, vec![]), 0.9)),
            Some(r) => Box::new(EfSgd::new(
                Box::new(PowerSgd::new(r, 1)),
                LrSchedule::paper_step(0.01, 4, 0, vec![]),
                0.9,
            )),
        };
        let (acc, _) = common::run_convnet(&dir, opt, 4, 300, 42);
        let b = simulate_step(&prof, scheme, 16, &NCCL);
        table.row(&[
            name.to_string(),
            format!("{acc:.1}%"),
            format!("{:.0} MB", data_per_epoch_mb(&prof, scheme)),
            format!("{:.0} ms", b.total() * 1e3),
            format!("{:+.0}%", (b.total() / sgd_total - 1.0) * 100.0),
        ]);
    }
    table.print();

    // ---- language modeling side -----------------------------------
    let prof = lstm_wikitext2();
    let sgd_total = simulate_step(&prof, Scheme::Sgd, 16, &NCCL).total();
    let mut table = Table::new(
        "Table 3b — PowerSGD rank sweep, LSTM/WikiText-2",
        &["Algorithm", "Perplexity (proxy)", "Data/epoch", "Time/batch", "vs SGD"],
    );
    for (name, scheme, rank) in [
        ("SGD", Scheme::Sgd, None),
        ("Rank 1", Scheme::PowerSgd { rank: 1 }, Some(1usize)),
        ("Rank 2", Scheme::PowerSgd { rank: 2 }, Some(2)),
        ("Rank 4", Scheme::PowerSgd { rank: 4 }, Some(4)),
    ] {
        let opt: Box<dyn DistOptimizer> = match rank {
            None => Box::new(Sgd::new(LrSchedule::paper_step(0.125, 4, 0, vec![]), 0.9)),
            Some(r) => Box::new(EfSgd::new(
                Box::new(PowerSgd::new(r, 1)),
                LrSchedule::paper_step(0.125, 4, 0, vec![]),
                0.9,
            )),
        };
        let (ppl, _) = common::run_lstm(&dir, opt, 4, 200, 42);
        let b = simulate_step(&prof, scheme, 16, &NCCL);
        table.row(&[
            name.to_string(),
            format!("{ppl:.1}"),
            format!("{:.0} MB", data_per_epoch_mb(&prof, scheme)),
            format!("{:.0} ms", b.total() * 1e3),
            format!("{:+.0}%", (b.total() / sgd_total - 1.0) * 100.0),
        ]);
    }
    table.print();

    // ---- per-layer compression (paper Tables 10 & 11) -------------
    for prof in [resnet18(), lstm_wikitext2()] {
        let mut t = Table::new(
            &format!("Per-tensor compression — {} (cf. Tables 10/11)", prof.name),
            &["Parameter", "Matrix shape", "Uncompressed", "Compression"],
        );
        for spec in &prof.registry.specs {
            match spec.matrix_dims() {
                Some((n, m)) => t.row(&[
                    spec.name.clone(),
                    format!("{n} x {m}"),
                    format!("{} KB", spec.bytes() / 1024),
                    format!("{:.0}/r x", spec.bytes() as f64 / spec.rank_r_bytes_uncapped(1) as f64),
                ]),
                None => t.row(&[
                    spec.name.clone(),
                    "-".into(),
                    format!("{} KB", spec.bytes() / 1024),
                    "None".into(),
                ]),
            };
        }
        t.row(&[
            "Total".into(),
            "".into(),
            format!("{} MB", prof.registry.total_bytes() / (1024 * 1024)),
            format!("{:.0}/r x", prof.registry.compression_ratio(1)),
        ]);
        t.print();
    }
}
