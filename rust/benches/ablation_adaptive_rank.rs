//! Ablation (extension, paper §6 future work): adaptive-rank PowerSGD.
//!
//! The paper picks one rank per task by hand (2 for CIFAR, 4 for the
//! LSTM, 32 for the transformer). The residual-controlled variant
//! (`compress::AdaptivePowerSgd`) adjusts rank online from the EF
//! residual. This bench compares fixed ranks against the adaptive
//! controller on the convnet proxy: accuracy, bytes, and the rank
//! trajectory.

mod common;

use powersgd::compress::{AdaptivePowerSgd, PowerSgd};
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule};
use powersgd::util::Table;

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };
    let lr = || LrSchedule::paper_step(0.01, 4, 0, vec![]);
    let mut table = Table::new(
        "Ablation — fixed vs adaptive rank (convnet proxy, 4 workers, 300 steps)",
        &["Compressor", "Test accuracy", "Bytes/step"],
    );
    for rank in [1usize, 2, 4] {
        let opt: Box<dyn DistOptimizer> =
            Box::new(EfSgd::new(Box::new(PowerSgd::new(rank, 1)), lr(), 0.9));
        let (acc, bytes) = common::run_convnet(&dir, opt, 4, 300, 42);
        table.row(&[format!("Fixed rank {rank}"), format!("{acc:.1}%"), format!("{bytes}")]);
    }
    let adaptive = AdaptivePowerSgd::new(1, 1, 8, 1);
    let opt: Box<dyn DistOptimizer> = Box::new(EfSgd::new(Box::new(adaptive), lr(), 0.9));
    let (acc, bytes) = common::run_convnet(&dir, opt, 4, 300, 42);
    table.row(&["Adaptive [1..8]".into(), format!("{acc:.1}%"), format!("{bytes}")]);
    table.print();
    println!("\nexpected: adaptive lands between rank-1 cost and rank-4 quality without hand tuning.");
}
