//! Paper Table 5: breakdown of time spent in one iteration of ResNet18
//! training into forward, backward, gradient exchange and coding, as
//! worker count grows — showing all-reduce decode stays constant while
//! all-gather decode scales with W.

mod common;

use powersgd::net::NCCL;
use powersgd::profiles::resnet18;
use powersgd::simulate::{simulate_step, Scheme};
use powersgd::util::Table;

fn main() {
    let prof = resnet18();
    for scheme in [Scheme::Sgd, Scheme::PowerSgd { rank: 2 }, Scheme::Signum] {
        let mut table = Table::new(
            &format!("Table 5 — per-step breakdown, {}", scheme.name()),
            &["Workers", "fwd", "bwd", "exchange", "encode+decode", "total"],
        );
        for w in [2usize, 4, 8, 16] {
            let b = simulate_step(&prof, scheme, w, &NCCL);
            table.row(&[
                format!("{w}"),
                format!("{:.0} ms", b.fwd * 1e3),
                format!("{:.0} ms", b.bwd * 1e3),
                format!("{:.1} ms", b.comm * 1e3),
                format!("{:.1} ms", (b.encode + b.decode) * 1e3),
                format!("{:.0} ms", b.total() * 1e3),
            ]);
        }
        table.print();
        println!();
    }

    // The two structural claims of Table 5:
    let p2 = simulate_step(&prof, Scheme::PowerSgd { rank: 2 }, 2, &NCCL);
    let p16 = simulate_step(&prof, Scheme::PowerSgd { rank: 2 }, 16, &NCCL);
    let s2 = simulate_step(&prof, Scheme::Signum, 2, &NCCL);
    let s16 = simulate_step(&prof, Scheme::Signum, 16, &NCCL);
    println!(
        "PowerSGD decode constant in W: {:.2} ms -> {:.2} ms (all-reduce pre-aggregates)",
        p2.decode * 1e3,
        p16.decode * 1e3
    );
    println!(
        "Signum decode scales with W:   {:.1} ms -> {:.1} ms (all-gather: W messages to vote over)",
        s2.decode * 1e3,
        s16.decode * 1e3
    );
}
