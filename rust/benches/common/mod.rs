//! Shared plumbing for the bench binaries (criterion is unavailable
//! offline; see rust/src/util/bench.rs for the in-tree harness).
//!
//! Each bench regenerates one table or figure of the paper. Training
//! benches run the *small-scale proxy* (synthetic data, reduced model) —
//! accuracy columns reproduce orderings, not absolute numbers; byte
//! columns are exact arithmetic over the paper's real layer shapes; and
//! timing columns come from the calibrated simulator. See DESIGN.md §7.
#![allow(dead_code)]

use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::{Classification, LmCorpus};
use powersgd::optim::DistOptimizer;
use powersgd::runtime::Runtime;

pub fn artifacts_dir() -> Option<String> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("mlp_train.manifest").exists() {
            return Some(dir.to_string());
        }
    }
    eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
    None
}

/// Train the convnet proxy; returns (test accuracy %, bytes/step).
pub fn run_convnet(
    dir: &str,
    opt: Box<dyn DistOptimizer>,
    workers: usize,
    steps: usize,
    seed: u64,
) -> (f64, u64) {
    let mut rt = Runtime::cpu(dir).unwrap();
    let train = rt.load("convnet_train").unwrap();
    let eval = rt.load("convnet_eval").unwrap();
    let cfg = TrainerConfig { workers, seed, eval_kind: EvalKind::Accuracy, ..Default::default() };
    let mut data = Classification::new(3 * 16 * 16, 10, 32, workers, seed);
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg).unwrap();
    trainer.train(&mut data, steps).unwrap();
    let acc = trainer.evaluate(&mut data).unwrap();
    (acc, trainer.metrics.total_bytes() / steps as u64)
}

/// Train the LSTM proxy; returns (perplexity, bytes/step).
pub fn run_lstm(
    dir: &str,
    opt: Box<dyn DistOptimizer>,
    workers: usize,
    steps: usize,
    seed: u64,
) -> (f64, u64) {
    let mut rt = Runtime::cpu(dir).unwrap();
    let train = rt.load("lstm_train").unwrap();
    let eval = rt.load("lstm_eval").unwrap();
    let cfg = TrainerConfig { workers, seed, eval_kind: EvalKind::Perplexity, ..Default::default() };
    let mut data = LmCorpus::new(1000, 8, 32, workers, seed);
    let mut trainer = Trainer::new(train, Some(eval), opt, cfg).unwrap();
    trainer.train(&mut data, steps).unwrap();
    let ppl = trainer.evaluate(&mut data).unwrap();
    (ppl, trainer.metrics.total_bytes() / steps as u64)
}

/// MiB formatting like the paper's MB columns.
pub fn mb(bytes: f64) -> String {
    format!("{:.0} MB", bytes / (1024.0 * 1024.0))
}
