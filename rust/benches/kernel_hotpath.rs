//! §4.2 cost comparison + compression-hot-path microbenchmarks.
//!
//! Paper: "computing the SVD of a stochastic gradient takes 673 ms ...
//! one full step of rank-2 PowerSGD, including communication between 16
//! workers, takes only 105 ms." We measure our native substrate on the
//! same shapes: the *ordering and the gap* must reproduce (SVD ≫
//! PowerSGD step). This bench is also the profiling entry point for
//! performance passes over the kernel hot path.
//!
//! Every kernel case now runs a **thread sweep** over the kernel pool
//! (DESIGN.md §11): 1/2/4/8 threads in full mode, 1 vs 4 in
//! `BENCH_QUICK=1` (the CI `bench-smoke` comparison artifact). The
//! 1-thread rows keep the historical case names so the JSON trajectory
//! stays comparable; t>1 rows append ` [t=N]` and every row carries a
//! `threads` metric. Kernel results are bitwise identical across the
//! sweep — only the wall-clock moves — and the headline records are
//! `powersgd_step/threads/N` with `speedup_x` vs the 1-thread step.
//!
//! The full step also runs with the span recorder off vs fully on
//! (`powersgd_step/tracing/{off,on}` plus an `overhead_x` record), so
//! the trace layer's hot-path cost has a standing trajectory next to
//! the thread-scaling one. The metrics registry (DESIGN.md §15) gets
//! the same treatment: `powersgd_step/metrics/{off,on}` with its own
//! `overhead_x` — counters and quality gauges are fixed static atomics,
//! so the pair pins the cost of the one-relaxed-load-when-off design.
//!
//! A **backend duel** section runs every GEMM kernel and the full step
//! single-threaded on both kernel backends (DESIGN.md §11): the blocked
//! `kernel/{nn,tn,nt}/blocked/...` rows against their
//! `kernel/{nn,tn,nt}/naive/...` reference twins, each carrying
//! `throughput_gflops` plus roofline-style context (arithmetic
//! intensity in flops/byte and achieved GB/s), and a `speedup_x`
//! record per kernel×shape. The headline `powersgd_step/kernel/{naive,
//! blocked}` pair times the whole compress step per backend. The
//! `*_gflops` metrics are throughput (higher is better); the
//! `bench-diff` gate compares them direction-reversed.
//!
//! Emits `BENCH_kernel_hotpath.json` for the CI `bench-smoke` artifact
//! trail. `BENCH_QUICK=1` shrinks shapes and iteration budgets (the SVD
//! drops to a smaller matrix) so the smoke job stays fast.

use powersgd::collectives::CommLog;
use powersgd::compress::{Compressor, PowerSgd};
use powersgd::linalg::{gram_schmidt_in_place, svd};
use powersgd::runtime::pool::{set_kernel_backend, set_threads, KernelBackend};
use powersgd::tensor::{
    matmul, matmul_at_b, matmul_into, matmul_nt_into, matmul_tn_into, Tensor,
};
use powersgd::util::{black_box, quick_mode, BenchJson, BenchRunner, Rng};

fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

fn main() {
    let quick = quick_mode();
    let mut rng = Rng::new(55);
    let mut json = BenchJson::new("kernel_hotpath");
    json.set_context("lockstep", "inproc");
    // Kernel microbenches drive no collectives — pin the pipeline axis
    // explicitly so the JSON stays diffable against fig_overlap's.
    json.set_pipeline("off");

    let sweep: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };

    // --- the paper's dominant layer shapes ---
    let shapes: &[(usize, usize)] = if quick {
        &[(512, 4608)]
    } else {
        &[(512, 4608), (2600, 650), (128, 1152)]
    };
    let ranks: &[usize] = if quick { &[2] } else { &[1, 2, 4] };
    let gs_shapes: &[(usize, usize)] = if quick {
        &[(512, 2)]
    } else {
        &[(512, 2), (2600, 4), (28869, 4)]
    };
    let step_shapes: Vec<(usize, usize)> = if quick {
        vec![(512, 4608)]
    } else {
        vec![(512, 4608), (512, 4608), (512, 4608), (256, 2304)]
    };
    let updates: Vec<Vec<Tensor>> = (0..1)
        .map(|_| step_shapes.iter().map(|&(n, m)| rand_tensor(&[n, m], &mut rng)).collect())
        .collect();
    let nlayers = step_shapes.len();

    let mut step_means: Vec<(usize, f64)> = Vec::new();
    for &t in sweep {
        set_threads(t);
        let tag = if t == 1 { String::new() } else { format!(" [t={t}]") };
        let mut runner = BenchRunner::from_env();

        let mut shape_rng = Rng::new(56);
        for &(n, m) in shapes {
            let a = rand_tensor(&[n, m], &mut shape_rng);
            for &r in ranks {
                let q = rand_tensor(&[m, r], &mut shape_rng);
                runner.bench(&format!("matmul M[{n}x{m}]·Q[r={r}]{tag}"), || {
                    black_box(matmul(&a, &q));
                });
            }
            let p = rand_tensor(&[n, 2], &mut shape_rng);
            runner.bench(&format!("matmul_tn Mᵀ[{n}x{m}]·P[r=2]{tag}"), || {
                black_box(matmul_at_b(&a, &p));
            });
            // The reconstruction (decompress) kernel.
            let phat = rand_tensor(&[n, 2], &mut shape_rng);
            let qn = rand_tensor(&[m, 2], &mut shape_rng);
            let mut rec = Tensor::zeros(&[n, m]);
            runner.bench(&format!("matmul_nt P̂[{n}x2]·Qᵀ[{m}]{tag}"), || {
                matmul_nt_into(&phat, &qn, &mut rec);
                black_box(rec.data()[0]);
            });
        }

        // --- Gram–Schmidt (the paper's "most expensive part") ---
        for &(n, r) in gs_shapes {
            let p0 = rand_tensor(&[n, r], &mut shape_rng);
            runner.bench(&format!("gram_schmidt [{n}x{r}]{tag}"), || {
                let mut p = p0.clone();
                gram_schmidt_in_place(&mut p);
                black_box(p);
            });
        }

        // --- full PowerSGD step over the ResNet18-scale matrix set ---
        let mut comp = PowerSgd::new(2, 1);
        let step_summary =
            runner.bench(&format!("PowerSGD rank-2 full step ({nlayers} layers){tag}"), || {
                let mut log = CommLog::default();
                black_box(comp.compress_aggregate(&updates, &mut log));
            });
        step_means.push((t, step_summary.mean));

        json.record_runner_tagged(&runner, &[("threads", t as f64)]);
    }

    // Thread-scaling headline: the rank-2 full-step speedup curve.
    let base = step_means[0].1;
    println!();
    for &(t, mean) in &step_means {
        let speedup = base / mean;
        println!("PowerSGD full step at {t} thread(s): {mean:.2} ms ({speedup:.2}x vs 1 thread)");
        json.record(
            &format!("powersgd_step/threads/{t}"),
            &[("threads", t as f64), ("mean_ms", mean), ("speedup_x", speedup)],
        );
    }

    // --- backend duel: blocked vs naive reference, single thread ---
    // The reference backend is the differential harness's executable
    // specification (tensor/reference.rs); timing it next to the
    // blocked kernels keeps the blocked-vs-naive speedup an honest,
    // standing record instead of a one-off claim. GFLOP/s uses the
    // textbook 2·n·m·r GEMM flop count; bytes are the compulsory
    // traffic (read both operands once, write the output once), so
    // `ai_flops_per_byte` and `gbytes_per_s` sketch where each shape
    // sits on the roofline.
    set_threads(1);
    let duel_r = 2usize;
    for &(n, m) in shapes {
        let mut duel_rng = Rng::new(57);
        let a = rand_tensor(&[n, m], &mut duel_rng);
        let b = rand_tensor(&[m, duel_r], &mut duel_rng);
        let p = rand_tensor(&[n, duel_r], &mut duel_rng);
        let q = rand_tensor(&[m, duel_r], &mut duel_rng);
        let mut nn_out = Tensor::zeros(&[n, duel_r]);
        let mut tn_out = Tensor::zeros(&[m, duel_r]);
        let mut nt_out = Tensor::zeros(&[n, m]);
        let flops = (2 * n * m * duel_r) as f64;
        // (kernel key, compulsory bytes) per GEMM variant; all three
        // share `flops` above.
        let cases: [(&str, f64); 3] = [
            ("nn", 4.0 * (n * m + m * duel_r + n * duel_r) as f64),
            ("tn", 4.0 * (n * m + n * duel_r + m * duel_r) as f64),
            ("nt", 4.0 * (n * duel_r + m * duel_r + n * m) as f64),
        ];
        let mut gflops_by = std::collections::HashMap::new();
        for (bname, backend) in
            [("naive", KernelBackend::Reference), ("blocked", KernelBackend::Blocked)]
        {
            set_kernel_backend(backend);
            let mut runner = BenchRunner::from_env();
            let means = [
                runner
                    .bench(&format!("kernel nn {n}x{m} r={duel_r} [{bname}]"), || {
                        matmul_into(&a, &b, &mut nn_out);
                        black_box(nn_out.data()[0]);
                    })
                    .mean,
                runner
                    .bench(&format!("kernel tn {n}x{m} r={duel_r} [{bname}]"), || {
                        matmul_tn_into(&a, &p, &mut tn_out);
                        black_box(tn_out.data()[0]);
                    })
                    .mean,
                runner
                    .bench(&format!("kernel nt {n}x{m} r={duel_r} [{bname}]"), || {
                        matmul_nt_into(&p, &q, &mut nt_out);
                        black_box(nt_out.data()[0]);
                    })
                    .mean,
            ];
            json.record_runner(&runner);
            for ((kname, bytes), mean_ms) in cases.iter().zip(means) {
                let secs = mean_ms / 1e3;
                let gf = flops / secs / 1e9;
                gflops_by.insert((*kname, bname), gf);
                json.record(
                    &format!("kernel/{kname}/{bname}/{n}x{m}r{duel_r}"),
                    &[
                        ("throughput_gflops", gf),
                        ("mean_ms", mean_ms),
                        ("ai_flops_per_byte", flops / bytes),
                        ("gbytes_per_s", bytes / secs / 1e9),
                    ],
                );
            }
        }
        for (kname, _) in &cases {
            let fast = gflops_by[&(*kname, "blocked")];
            let slow = gflops_by[&(*kname, "naive")];
            println!(
                "kernel {kname} {n}x{m} r={duel_r}: blocked {fast:.2} GFLOP/s vs naive {slow:.2} ({:.2}x)",
                fast / slow
            );
            json.record(
                &format!("kernel/{kname}/speedup/{n}x{m}r{duel_r}"),
                &[("speedup_x", fast / slow)],
            );
        }
    }

    // The same duel over the whole compress step: GEMM sweeps,
    // all-reduces, Gram–Schmidt, reconstruction, per backend.
    let mut step_by_backend: Vec<f64> = Vec::new();
    for (bname, backend) in
        [("naive", KernelBackend::Reference), ("blocked", KernelBackend::Blocked)]
    {
        set_kernel_backend(backend);
        let mut comp = PowerSgd::new(2, 1);
        let mut runner = BenchRunner::from_env();
        let summary =
            runner.bench(&format!("PowerSGD rank-2 full step [kernel={bname}]"), || {
                let mut log = CommLog::default();
                black_box(comp.compress_aggregate(&updates, &mut log));
            });
        step_by_backend.push(summary.mean);
        json.record_runner(&runner);
        json.record(
            &format!("powersgd_step/kernel/{bname}"),
            &[("mean_ms", summary.mean)],
        );
    }
    set_kernel_backend(KernelBackend::Blocked);
    let duel_speedup = step_by_backend[0] / step_by_backend[1];
    println!(
        "full step: blocked {:.2} ms vs naive {:.2} ms ({duel_speedup:.2}x)",
        step_by_backend[1], step_by_backend[0]
    );
    json.record("powersgd_step/kernel/speedup", &[("speedup_x", duel_speedup)]);

    // --- tracing overhead: the identical full step with the span
    // recorder off vs fully on (timing + trace). The disabled path is
    // one relaxed atomic load per span site (DESIGN.md §13), so this
    // off-vs-on pair is the standing record of what observability
    // costs on the hot path.
    set_threads(1);
    let mut traced_means: Vec<f64> = Vec::new();
    for (label, on) in [("off", false), ("on", true)] {
        powersgd::obs::enable_timing(on);
        powersgd::obs::enable_trace(on);
        let mut comp = PowerSgd::new(2, 1);
        let mut runner = BenchRunner::from_env();
        let summary =
            runner.bench(&format!("PowerSGD rank-2 full step [tracing={label}]"), || {
                let mut log = CommLog::default();
                black_box(comp.compress_aggregate(&updates, &mut log));
            });
        traced_means.push(summary.mean);
        json.record_runner(&runner);
        json.record(
            &format!("powersgd_step/tracing/{label}"),
            &[("traced", if on { 1.0 } else { 0.0 }), ("mean_ms", summary.mean)],
        );
    }
    powersgd::obs::enable_timing(false);
    powersgd::obs::enable_trace(false);
    powersgd::obs::drain_tracks(); // free the recorded span buffers
    let overhead = traced_means[1] / traced_means[0];
    println!(
        "tracing overhead on the full step: {overhead:.3}x (off {:.2} ms, on {:.2} ms)",
        traced_means[0], traced_means[1]
    );
    json.record("powersgd_step/tracing/overhead", &[("overhead_x", overhead)]);

    // --- metrics overhead: the same off/on pair for the run-health
    // registry (DESIGN.md §15). With the bit clear every record site is
    // one relaxed atomic load; with it set the step additionally pays
    // the quality-gauge reductions (EF residual / approx-error norms)
    // and the counter/histogram stores.
    let mut metric_means: Vec<f64> = Vec::new();
    for (label, on) in [("off", false), ("on", true)] {
        powersgd::obs::enable_metrics(on);
        let mut comp = PowerSgd::new(2, 1);
        let mut runner = BenchRunner::from_env();
        let summary =
            runner.bench(&format!("PowerSGD rank-2 full step [metrics={label}]"), || {
                let mut log = CommLog::default();
                black_box(comp.compress_aggregate(&updates, &mut log));
            });
        metric_means.push(summary.mean);
        json.record_runner(&runner);
        json.record(
            &format!("powersgd_step/metrics/{label}"),
            &[("metered", if on { 1.0 } else { 0.0 }), ("mean_ms", summary.mean)],
        );
    }
    powersgd::obs::enable_metrics(false);
    let m_overhead = metric_means[1] / metric_means[0];
    println!(
        "metrics overhead on the full step: {m_overhead:.3}x (off {:.2} ms, on {:.2} ms)",
        metric_means[0], metric_means[1]
    );
    json.record("powersgd_step/metrics/overhead", &[("overhead_x", m_overhead)]);

    // --- the Atomo cost: full SVD of the dominant layer (serial; the
    // Jacobi SVD is not pool-parallel) ---
    set_threads(1);
    let (svd_n, svd_m) = if quick { (128, 1152) } else { (512, 4608) };
    let a = rand_tensor(&[svd_n, svd_m], &mut rng);
    let mut svd_runner = BenchRunner::once(if quick { 1 } else { 2 });
    let svd_summary =
        svd_runner.bench(&format!("Jacobi SVD {svd_n}x{svd_m} (Atomo per-layer cost)"), || {
            black_box(svd(&a));
        });

    println!(
        "\n§4.2 reproduction: SVD {:.0} ms vs PowerSGD step {:.1} ms — {:.0}x gap (paper: 673 vs 105 ms, 6.4x)",
        svd_summary.mean,
        base,
        svd_summary.mean / base
    );

    json.record_runner(&svd_runner);
    json.record(
        "svd_vs_powersgd_step",
        &[("gap_x", svd_summary.mean / base)],
    );
    json.write().expect("write BENCH_kernel_hotpath.json");
}
