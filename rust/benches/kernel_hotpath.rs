//! §4.2 cost comparison + compression-hot-path microbenchmarks.
//!
//! Paper: "computing the SVD of a stochastic gradient takes 673 ms ...
//! one full step of rank-2 PowerSGD, including communication between 16
//! workers, takes only 105 ms." We measure our native substrate on the
//! same shapes: the *ordering and the gap* must reproduce (SVD ≫
//! PowerSGD step). This bench is also the profiling entry point for the
//! performance pass (EXPERIMENTS.md §Perf).

use powersgd::collectives::CommLog;
use powersgd::compress::{Compressor, PowerSgd};
use powersgd::linalg::{gram_schmidt_in_place, svd};
use powersgd::tensor::{matmul, matmul_at_b, Tensor};
use powersgd::util::{black_box, BenchRunner, Rng};

fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
    let mut t = Tensor::zeros(shape);
    rng.fill_normal(t.data_mut(), 1.0);
    t
}

fn main() {
    let mut rng = Rng::new(55);
    let mut runner = BenchRunner::new();

    // --- the paper's dominant layer shapes ---
    for &(n, m) in &[(512usize, 4608usize), (2600, 650), (128, 1152)] {
        let a = rand_tensor(&[n, m], &mut rng);
        for &r in &[1usize, 2, 4] {
            let q = rand_tensor(&[m, r], &mut rng);
            runner.bench(&format!("matmul M[{n}x{m}]·Q[r={r}]"), || {
                black_box(matmul(&a, &q));
            });
        }
        let p = rand_tensor(&[n, 2], &mut rng);
        runner.bench(&format!("matmul_tn Mᵀ[{n}x{m}]·P[r=2]"), || {
            black_box(matmul_at_b(&a, &p));
        });
    }

    // --- Gram–Schmidt (the paper's "most expensive part") ---
    for &(n, r) in &[(512usize, 2usize), (2600, 4), (28869, 4)] {
        let p0 = rand_tensor(&[n, r], &mut rng);
        runner.bench(&format!("gram_schmidt [{n}x{r}]"), || {
            let mut p = p0.clone();
            gram_schmidt_in_place(&mut p);
            black_box(p);
        });
    }

    // --- full PowerSGD step over the ResNet18-scale matrix set ---
    let shapes: Vec<(usize, usize)> = vec![(512, 4608), (512, 4608), (512, 4608), (256, 2304)];
    let updates: Vec<Vec<Tensor>> = (0..1)
        .map(|_| shapes.iter().map(|&(n, m)| rand_tensor(&[n, m], &mut rng)).collect())
        .collect();
    let mut comp = PowerSgd::new(2, 1);
    let step_summary = runner.bench("PowerSGD rank-2 full step (4 big layers)", || {
        let mut log = CommLog::default();
        black_box(comp.compress_aggregate(&updates, &mut log));
    });

    // --- the Atomo cost: full SVD of the dominant layer ---
    let a = rand_tensor(&[512, 4608], &mut rng);
    let mut svd_runner = BenchRunner::once(2);
    let svd_summary = svd_runner.bench("Jacobi SVD 512x4608 (Atomo per-layer cost)", || {
        black_box(svd(&a));
    });

    println!(
        "\n§4.2 reproduction: SVD {:.0} ms vs PowerSGD step {:.1} ms — {:.0}x gap (paper: 673 vs 105 ms, 6.4x)",
        svd_summary.mean,
        step_summary.mean,
        svd_summary.mean / step_summary.mean
    );
}
