//! Paper Appendix B: timings of collective communication operations on
//! the NCCL and GLOO backends as message size grows — the cost-model
//! curves every simulated table rests on.

mod common;

use powersgd::collectives::CollKind;
use powersgd::net::{GLOO, NCCL};
use powersgd::util::Table;

fn main() {
    for kind in [CollKind::AllReduce, CollKind::AllGather, CollKind::ReduceBroadcast] {
        let mut table = Table::new(
            &format!("Appendix B — {kind:?} time vs message size (16 workers)"),
            &["Message", "NCCL", "GLOO", "GLOO/NCCL"],
        );
        for mb in [0.01f64, 0.1, 1.0, 8.0, 43.0, 110.0] {
            let bytes = (mb * 1e6) as u64;
            let tn = NCCL.time(kind, bytes, 16) * 1e3;
            let tg = GLOO.time(kind, bytes, 16) * 1e3;
            table.row(&[
                format!("{mb} MB"),
                format!("{tn:.2} ms"),
                format!("{tg:.2} ms"),
                format!("{:.1}x", tg / tn),
            ]);
        }
        table.print();
        println!();
    }
    println!("paper shape: NCCL dominates at every size; all-gather grows with W while");
    println!("ring all-reduce saturates; PS reduce+broadcast is strictly worse than all-reduce.");
}
