//! Paper Table 4: comparing compression operators for EF-SGD in a
//! unified setting, medium (~32×) and high (~128×) compression regimes.
//!
//! Accuracy: real proxy training. Sent/epoch + all-reduce capability:
//! exact. Time/batch: calibrated simulator on the real ResNet18 shapes.

mod common;

use powersgd::compress::*;
use powersgd::net::NCCL;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd};
use powersgd::profiles::resnet18;
use powersgd::simulate::{data_per_epoch_mb, simulate_step, Scheme};
use powersgd::util::Table;

fn case(name: &str, rank: usize, seed: u64) -> (Box<dyn DistOptimizer>, Scheme, bool) {
    let lr = LrSchedule::paper_step(0.01, 4, 0, vec![]);
    match name {
        "Rank" => (
            Box::new(EfSgd::new(Box::new(PowerSgd::new(rank, seed)), lr, 0.9)),
            Scheme::PowerSgd { rank },
            true,
        ),
        "Random Block" => (
            Box::new(EfSgd::new(Box::new(RandomBlock::new(rank, seed)), lr, 0.9)),
            Scheme::RandomBlock { rank },
            true,
        ),
        "Random K" => (
            Box::new(EfSgd::new(Box::new(RandomK::new(rank, seed)), lr, 0.9)),
            Scheme::RandomK { rank },
            true,
        ),
        "Sign+Norm" => (
            Box::new(EfSgd::new(Box::new(SignNorm::new()), lr, 0.9)),
            Scheme::SignNorm,
            false,
        ),
        "Top K" => (
            Box::new(EfSgd::new(Box::new(TopK::new(rank)), lr, 0.9)),
            Scheme::TopK { rank },
            false,
        ),
        other => panic!("{other}"),
    }
}

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };
    let prof = resnet18();

    for (regime, rank) in [("Medium (~rank 7 budget)", 7usize), ("High (~rank 2 budget)", 2)] {
        let mut table = Table::new(
            &format!("Table 4 — {regime}"),
            &["Compressor", "Test acc (proxy)", "Sent/epoch", "All-reduce", "Time/batch (sim)"],
        );
        // baseline row
        let (acc, _) = common::run_convnet(
            &dir,
            Box::new(Sgd::new(LrSchedule::paper_step(0.01, 4, 0, vec![]), 0.9)),
            4,
            300,
            42,
        );
        let b = simulate_step(&prof, Scheme::Sgd, 16, &NCCL);
        table.row(&[
            "No compression".into(),
            format!("{acc:.1}%"),
            format!("{:.0} MB", data_per_epoch_mb(&prof, Scheme::Sgd)),
            "yes".into(),
            format!("{:.0} ms", b.total() * 1e3),
        ]);
        for name in ["Rank", "Random Block", "Random K", "Sign+Norm", "Top K"] {
            if name == "Sign+Norm" && rank != 7 {
                // sign compression has a fixed ratio (~32×): only in medium
                continue;
            }
            let (opt, scheme, allreduce) = case(name, rank, 1);
            let (acc, _) = common::run_convnet(&dir, opt, 4, 300, 42);
            let b = simulate_step(&prof, scheme, 16, &NCCL);
            let label = if name == "Rank" { format!("Rank {rank}") } else { name.to_string() };
            table.row(&[
                label,
                format!("{acc:.1}%"),
                format!("{:.0} MB", data_per_epoch_mb(&prof, scheme)),
                if allreduce { "yes".into() } else { "NO".into() },
                format!("{:.0} ms", b.total() * 1e3),
            ]);
        }
        table.print();
        println!();
    }
    println!("paper: only PowerSGD and Random Block beat full-precision SGD on time;");
    println!("at high compression only PowerSGD holds the target accuracy.");
}
