//! Paper Table 1: rank-based compression with and without error
//! feedback — the biased PowerSGD (with EF) must beat the unbiased
//! linear rank-r compressor on test accuracy, at comparable volume.
//!
//! Paper (CIFAR10/ResNet18, 300 epochs):
//!   SGD 94.3% / 1023 MB  · Rank1 93.6% / 4 MB · Rank2 94.4% / 8 MB
//!   Unbiased Rank1 71.2% / 3 MB · Unbiased Rank2 75.9% / 4 MB
//! Ours: convnet proxy, 4 workers, 300 steps — same ordering expected.

mod common;

use powersgd::compress::{PowerSgd, UnbiasedRank};
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd};
use powersgd::profiles::resnet18;
use powersgd::util::Table;

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };
    let lr = || LrSchedule::paper_step(0.01, 4, 0, vec![]);
    // Unbiased variants use a lower LR or they diverge outright; the
    // paper tuned per-algorithm LRs for non-EF methods (Appendix I).
    let cases: Vec<(&str, Box<dyn DistOptimizer>)> = vec![
        ("SGD", Box::new(Sgd::new(lr(), 0.9))),
        ("Rank-1 PowerSGD", Box::new(EfSgd::new(Box::new(PowerSgd::new(1, 1)), lr(), 0.9))),
        ("Rank-2 PowerSGD", Box::new(EfSgd::new(Box::new(PowerSgd::new(2, 1)), lr(), 0.9))),
        (
            "Unbiased Rank 1",
            Box::new(EfSgd::new(Box::new(UnbiasedRank::new(1, 1)), LrSchedule::paper_step(0.002, 4, 0, vec![]), 0.0).without_error_feedback()),
        ),
        (
            "Unbiased Rank 2",
            Box::new(EfSgd::new(Box::new(UnbiasedRank::new(2, 1)), LrSchedule::paper_step(0.002, 4, 0, vec![]), 0.0).without_error_feedback()),
        ),
    ];

    // Paper-scale data volumes computed over the real ResNet18 shapes.
    let prof = resnet18();
    let epoch_mb = |per_step: u64| {
        common::mb(per_step as f64 * prof.steps_per_epoch)
    };
    let paper_vol: &[(&str, u64)] = &[
        ("SGD", prof.registry.total_bytes()),
        ("Rank-1 PowerSGD", prof.registry.total_rank_r_bytes_uncapped(1)),
        ("Rank-2 PowerSGD", prof.registry.total_rank_r_bytes_uncapped(2)),
        ("Unbiased Rank 1", prof.registry.total_rank_r_bytes_uncapped(1) / 2),
        ("Unbiased Rank 2", prof.registry.total_rank_r_bytes_uncapped(2) / 2),
    ];

    let mut table = Table::new(
        "Table 1 — rank-based compression with/without error feedback",
        &["Algorithm", "Test accuracy (proxy)", "Data/epoch (paper shapes)"],
    );
    let mut accs = Vec::new();
    for (name, opt) in cases {
        let (acc, _bytes) = common::run_convnet(&dir, opt, 4, 300, 42);
        let vol = paper_vol.iter().find(|(n, _)| *n == name).unwrap().1;
        table.row(&[name.to_string(), format!("{acc:.1}%"), epoch_mb(vol)]);
        accs.push((name, acc));
    }
    table.print();

    // The paper's qualitative claims:
    let get = |n: &str| accs.iter().find(|(m, _)| *m == n).unwrap().1;
    let ok1 = get("Rank-2 PowerSGD") > get("Unbiased Rank 2") + 5.0;
    let ok2 = get("Rank-1 PowerSGD") > get("Unbiased Rank 1") + 5.0;
    let ok3 = (get("Rank-2 PowerSGD") - get("SGD")).abs() < 6.0;
    println!(
        "\nchecks: biased+EF beats unbiased (rank2): {ok1}; (rank1): {ok2}; rank-2 ~ SGD: {ok3}"
    );
}
