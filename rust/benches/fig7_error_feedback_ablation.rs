//! Paper Figure 7 (Appendix E): PowerSGD with and without error
//! feedback. Without EF the method does not converge to a good
//! accuracy at all — we regenerate the two convergence curves.

mod common;

use powersgd::compress::PowerSgd;
use powersgd::coordinator::{EvalKind, Trainer, TrainerConfig};
use powersgd::data::Classification;
use powersgd::optim::{EfSgd, LrSchedule};
use powersgd::runtime::Runtime;
use powersgd::util::Table;

fn curve(dir: &str, ef: bool) -> Vec<(usize, f64)> {
    let mut rt = Runtime::cpu(dir).unwrap();
    let train = rt.load("convnet_train").unwrap();
    let eval = rt.load("convnet_eval").unwrap();
    let inner = Box::new(PowerSgd::new(2, 1));
    let mut opt = EfSgd::new(inner, LrSchedule::paper_step(0.01, 4, 0, vec![]), 0.9);
    if !ef {
        opt = opt.without_error_feedback();
    }
    let cfg = TrainerConfig {
        workers: 4,
        eval_every: 30,
        eval_kind: EvalKind::Accuracy,
        ..Default::default()
    };
    let mut data = Classification::new(3 * 16 * 16, 10, 32, 4, 42);
    let mut trainer = Trainer::new(train, Some(eval), Box::new(opt), cfg).unwrap();
    trainer.train(&mut data, 300).unwrap();
    trainer.metrics.evals.clone()
}

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };
    let with_ef = curve(&dir, true);
    let without = curve(&dir, false);
    let mut table = Table::new(
        "Figure 7 — rank-2 PowerSGD with/without error feedback (accuracy vs step)",
        &["Step", "With EF", "Without EF"],
    );
    for ((s, a), (_, b)) in with_ef.iter().zip(without.iter()) {
        table.row(&[format!("{s}"), format!("{a:.1}%"), format!("{b:.1}%")]);
    }
    table.print();
    let final_ef = with_ef.last().unwrap().1;
    let final_no = without.last().unwrap().1;
    println!("\nfinal: EF {final_ef:.1}% vs no-EF {final_no:.1}% (paper: no-EF fails to reach target)");
}
