//! Paper Table 6: SGD vs Spectral Atomo vs Signum vs rank-2 PowerSGD on
//! CIFAR10. Paper: 94.3/92.6/93.6/94.4 % accuracy; 312/948/301/239 ms.

mod common;

use powersgd::compress::{Atomo, PowerSgd};
use powersgd::net::NCCL;
use powersgd::optim::{DistOptimizer, EfSgd, LrSchedule, Sgd, SignumOpt};
use powersgd::profiles::resnet18;
use powersgd::simulate::{data_per_epoch_mb, simulate_step, Scheme};
use powersgd::util::Table;

fn main() {
    let Some(dir) = common::artifacts_dir() else { return };
    let prof = resnet18();
    let cases: Vec<(&str, Box<dyn DistOptimizer>, Scheme)> = vec![
        ("SGD", Box::new(Sgd::new(LrSchedule::paper_step(0.01, 4, 0, vec![]), 0.9)), Scheme::Sgd),
        (
            // Atomo runs without EF, separately tuned LR (Appendix I)
            "Atomo (rank 2)",
            Box::new(
                EfSgd::new(Box::new(Atomo::new(2, 1)), LrSchedule::paper_step(0.002, 4, 0, vec![]), 0.0)
                    .without_error_feedback(),
            ),
            Scheme::Atomo { rank: 2 },
        ),
        (
            // Signum: sign-of-momentum + majority vote, tiny LR
            "Signum",
            Box::new(SignumOpt::new(LrSchedule::paper_step(0.0005, 4, 0, vec![]), 0.9)),
            Scheme::Signum,
        ),
        (
            "Rank 2",
            Box::new(EfSgd::new(Box::new(PowerSgd::new(2, 1)), LrSchedule::paper_step(0.01, 4, 0, vec![]), 0.9)),
            Scheme::PowerSgd { rank: 2 },
        ),
    ];

    let sgd_total = simulate_step(&prof, Scheme::Sgd, 16, &NCCL).total();
    let mut table = Table::new(
        "Table 6 — CIFAR10(-proxy): SGD vs Atomo vs Signum vs PowerSGD",
        &["Algorithm", "Test acc (proxy)", "Data/epoch", "Time/batch (sim)", "vs SGD"],
    );
    for (name, opt, scheme) in cases {
        let (acc, _) = common::run_convnet(&dir, opt, 4, 300, 42);
        let b = simulate_step(&prof, scheme, 16, &NCCL);
        table.row(&[
            name.to_string(),
            format!("{acc:.1}%"),
            format!("{:.0} MB", data_per_epoch_mb(&prof, scheme)),
            format!("{:.0} ms", b.total() * 1e3),
            format!("{:+.0}%", (b.total() / sgd_total - 1.0) * 100.0),
        ]);
    }
    table.print();
    println!("\npaper shape: Atomo ~3x slower than SGD; Signum ~SGD; PowerSGD fastest AND most accurate.");
}
